//! Preprocessing (Section III-A): materialize every local score once.
//!
//! The paper stores `ls(i, π)` in a hash table keyed by `(v_i, π_i)`. With
//! the fixed subset layout of `combinatorics::layout`, a *dense* table
//! `[n × S]` gives the same O(1) lookup with perfect locality and doubles
//! as the operand uploaded to the accelerator. Entries where `i ∈ π` are
//! poisoned with a large negative sentinel (they can never be selected —
//! the consistency test also rejects them — but the sentinel makes misuse
//! loud).
//!
//! Counting engine (DESIGN.md §14): the subset DFS shares a
//! [`PrefixCounter`] so descending from π to π∪{m} refines parent-config
//! codes incrementally. `--counting naive` swaps in the reference
//! [`CountsWorkspace`] path (full re-encode per cell) — both emit configs
//! in ascending code order and fold scores through the same math, so the
//! stores are bit-identical. For large row counts the prefix engine
//! switches to a chunked mode: row-chunks × tiles fan across the
//! executor, accumulating partial histograms that merge commutatively.
//!
//! `FullScoreTable` is the "all possible parent sets" variant used by the
//! Table V study: bitmask-indexed, exhaustive over all `2^(n-1)` parent
//! sets per node, feasible only for small n (the paper hit the same wall —
//! its Table V stops at 20 nodes, and its 37-node runs never use it).

use std::sync::Arc;

use super::adcache::CountCacheRef;
use super::bde::{BdeParams, LocalScorer};
use super::counts::{CountingConfig, CountingMode, CountsWorkspace, DENSE_LIMIT};
use super::lgamma::log10_gamma;
use super::prefix::PrefixCounter;
use crate::combinatorics::{BinomialTable, RestrictedLayout, SubsetLayout};
use crate::data::Dataset;
use crate::exec::{
    plan_ragged_tiles, plan_tiles, split_by_tiles, DispatchStats, ExecConfig, KernelExecutor, Tile,
};

/// Sentinel for invalid (node ∈ parents) entries. f32-safe, far below any
/// real log score, and still far from f32 −inf so sums stay finite.
pub const NEG_SENTINEL: f32 = -1.0e30;

/// Dense local-score table over a bounded subset layout: `[n × S]` when
/// unrestricted, ragged `Σ_i C(k_i, ≤s)` rows when built over a
/// [`RestrictedLayout`] (candidate-parent pools).
pub struct ScoreTable {
    /// Global dense layout — `Some` only for unrestricted builds. A
    /// restricted table is natively ragged and never materializes the
    /// global `C(n, ≤s)` translation table (DESIGN.md §16).
    layout: Option<SubsetLayout>,
    n: usize,
    /// Unrestricted: row-major `data[i * S + j] = ls(i, subset_j)`.
    /// Restricted: concatenated ragged rows in restricted-cell order.
    data: Vec<f32>,
    /// The candidate-parent restriction this table was built over, if
    /// any. `None` keeps every accessor on the classic dense path.
    restrict: Option<Arc<RestrictedLayout>>,
}

impl ScoreTable {
    /// Compute the full table: every node × every subset with `|π| ≤ s`,
    /// parallelized across `threads` workers with balanced tile
    /// dispatch (see [`Self::build_with`]).
    pub fn build(data: &Dataset, params: BdeParams, s: usize, threads: usize) -> Self {
        Self::build_with(data, params, s, &ExecConfig::balanced(threads))
    }

    /// Tiled build through the kernel execution layer: the `[n × S]`
    /// grid is cut into row-aligned tiles (`cfg.tile` cells each; `0` =
    /// one tile per row) and dispatched under `cfg.schedule`. Each cell
    /// is a pure function of `(node, subset)` written exactly once, so
    /// the table is **bit-identical for any thread count, schedule, or
    /// tile size** — and sub-row tiles keep every core busy even when
    /// `threads > n` (the old per-node buckets clamped to `n` workers).
    pub fn build_with(data: &Dataset, params: BdeParams, s: usize, cfg: &ExecConfig) -> Self {
        Self::build_stats_with(data, params, s, cfg).0
    }

    /// [`Self::build_with`] returning the per-tile dispatch profile
    /// (max/mean tile cost, worker imbalance) for benches and the
    /// `--log-level debug` histogram.
    pub fn build_stats_with(
        data: &Dataset,
        params: BdeParams,
        s: usize,
        cfg: &ExecConfig,
    ) -> (Self, DispatchStats) {
        Self::build_counted_with(data, params, s, cfg, &CountingConfig::default())
    }

    /// [`Self::build_stats_with`] with an explicit counting-engine
    /// selection: `counting.mode` picks prefix-cached vs naive
    /// re-encoding (bit-identical outputs), `counting.chunk_rows`
    /// controls the row-chunked path for large datasets.
    pub fn build_counted_with(
        data: &Dataset,
        params: BdeParams,
        s: usize,
        cfg: &ExecConfig,
        counting: &CountingConfig,
    ) -> (Self, DispatchStats) {
        let n = data.cols();
        let layout = SubsetLayout::new(n, s);
        let total = layout.total();
        let mut table = vec![0f32; n * total];

        let tiles = plan_tiles(n, total, cfg.tile);
        let exec = cfg.executor();
        let stats = {
            let grid = Grid::Full(&layout);
            let slices = split_by_tiles(&mut table, &tiles);
            match counting.chunk_for(data.rows()) {
                Some(chunk) => fill_tiles_chunked(
                    data,
                    params,
                    &grid,
                    exec.as_ref(),
                    &tiles,
                    &slices,
                    counting,
                    chunk,
                ),
                None => {
                    fill_tiles(data, params, &grid, exec.as_ref(), &tiles, &slices, counting)
                }
            }
        };
        crate::debug!(
            "dense build [{n} x {total}] via {}/{} ({} counting): {}",
            exec.name(),
            cfg.schedule.name(),
            counting.mode.name(),
            stats.summary()
        );
        (ScoreTable { layout: Some(layout), n, data: table, restrict: None }, stats)
    }

    /// Restricted build: compute only the cells of each node's
    /// candidate-pool subset space (`C(k_i, ≤s)` per node instead of
    /// `C(n, ≤s)`), tiled over the ragged per-node rows. Cells are pure
    /// functions of `(node, global subset)`, so a full-pool restriction
    /// (`k_i = n−1`) reproduces the unrestricted table's values bit for
    /// bit on every non-self subset.
    pub fn build_restricted_with(
        data: &Dataset,
        params: BdeParams,
        rl: &Arc<RestrictedLayout>,
        cfg: &ExecConfig,
    ) -> Self {
        Self::build_restricted_stats_with(data, params, rl, cfg).0
    }

    /// [`Self::build_restricted_with`] returning the ragged-tile
    /// dispatch profile.
    pub fn build_restricted_stats_with(
        data: &Dataset,
        params: BdeParams,
        rl: &Arc<RestrictedLayout>,
        cfg: &ExecConfig,
    ) -> (Self, DispatchStats) {
        Self::build_restricted_counted_with(data, params, rl, cfg, &CountingConfig::default())
    }

    /// [`Self::build_restricted_stats_with`] with an explicit
    /// counting-engine selection (see [`Self::build_counted_with`]).
    pub fn build_restricted_counted_with(
        data: &Dataset,
        params: BdeParams,
        rl: &Arc<RestrictedLayout>,
        cfg: &ExecConfig,
        counting: &CountingConfig,
    ) -> (Self, DispatchStats) {
        let n = data.cols();
        assert_eq!(rl.n(), n, "restriction and dataset disagree on n");
        let cells = rl.total_cells();
        let mut table = vec![0f32; cells];
        let tiles = plan_ragged_tiles(&rl.row_lens(), cfg.tile);
        let exec = cfg.executor();
        let stats = {
            let grid = Grid::Restricted(rl.as_ref());
            let slices = split_by_tiles(&mut table, &tiles);
            match counting.chunk_for(data.rows()) {
                Some(chunk) => fill_tiles_chunked(
                    data,
                    params,
                    &grid,
                    exec.as_ref(),
                    &tiles,
                    &slices,
                    counting,
                    chunk,
                ),
                None => {
                    fill_tiles(data, params, &grid, exec.as_ref(), &tiles, &slices, counting)
                }
            }
        };
        crate::debug!(
            "restricted dense build [{n} rows, {cells} cells] via {}/{} ({} counting): {}",
            exec.name(),
            cfg.schedule.name(),
            counting.mode.name(),
            stats.summary()
        );
        (ScoreTable { layout: None, n, data: table, restrict: Some(rl.clone()) }, stats)
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Global dense subset layout (shared with scorers and the runtime
    /// upload). Panics for restricted tables — the native ragged space
    /// has no global layout; go through [`Self::restriction`] instead.
    pub fn layout(&self) -> &SubsetLayout {
        self.layout.as_ref().expect(
            "restricted score table is natively ragged and holds no global dense layout \
             — address cells through restriction()/get_cell/score_of",
        )
    }

    /// The layout as the `Option` it is: `None` for restricted builds.
    pub fn layout_opt(&self) -> Option<&SubsetLayout> {
        self.layout.as_ref()
    }

    /// Parent-set size bound `s`.
    pub fn s(&self) -> usize {
        match &self.restrict {
            Some(rl) => rl.s(),
            None => self.layout().s(),
        }
    }

    /// Number of subsets per node row (the paper's S); dense only.
    pub fn subsets(&self) -> usize {
        self.layout().total()
    }

    /// Score of `node` with the subset at **global** layout index `idx`.
    /// Dense tables only — a restricted table has no global index space
    /// and panics; pool-aware readers use [`Self::get_cell`] /
    /// [`Self::score_of`].
    #[inline]
    pub fn get(&self, node: usize, idx: usize) -> f32 {
        assert!(
            self.restrict.is_none(),
            "global-index get on a native-ragged restricted table — use get_cell/score_of"
        );
        self.data[node * self.dense_total() + idx]
    }

    /// Direct read in the store's cell space: for unrestricted tables
    /// the cell space *is* the global layout; restricted tables index
    /// their ragged rows directly (the pool-aware engines' fast path).
    #[inline]
    pub fn get_cell(&self, node: usize, cell: usize) -> f32 {
        match &self.restrict {
            None => self.data[node * self.dense_total() + cell],
            Some(rl) => self.data[rl.row_start(node) + cell],
        }
    }

    /// Subsets per dense row without touching the layout accessor's
    /// panic path (`data` is exactly `n` rows).
    #[inline]
    fn dense_total(&self) -> usize {
        self.data.len() / self.n
    }

    /// Score row of one node (restricted tables: the ragged pool row in
    /// restricted-cell order).
    pub fn row(&self, node: usize) -> &[f32] {
        match &self.restrict {
            None => {
                let s = self.dense_total();
                &self.data[node * s..(node + 1) * s]
            }
            Some(rl) => {
                let start = rl.row_start(node);
                &self.data[start..start + rl.row_len(node)]
            }
        }
    }

    /// The candidate-parent restriction this table was built over.
    pub fn restriction(&self) -> Option<&RestrictedLayout> {
        self.restrict.as_deref()
    }

    /// Cells the table stores explicitly (`n · S` unrestricted,
    /// `Σ_i C(k_i, ≤s)` restricted).
    pub fn cells(&self) -> usize {
        self.data.len()
    }

    /// Whole `[n × S]` buffer (row-major) — uploaded to the device once.
    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    /// Convenience: score of `node` with an explicit sorted parent set.
    /// Works across both index spaces — restricted tables resolve the
    /// subset through the pool ([`NEG_SENTINEL`] when any member is
    /// outside it), dense tables through the global layout.
    pub fn score_of(&self, node: usize, parents: &[usize]) -> f32 {
        match &self.restrict {
            Some(rl) => match rl.cell_index_of(node, parents) {
                Some(cell) => self.data[rl.row_start(node) + cell],
                None => NEG_SENTINEL,
            },
            None => self.get(node, self.layout().index_of(parents)),
        }
    }

    /// Add the pairwise-prior contribution (Eq. 9): for every entry,
    /// `Σ_{m ∈ π} PPF(i, m)`. `ppf` is row-major `[n × n]`,
    /// `ppf[i*n + m] = PPF(i, m)` (prior on edge m → i).
    pub fn add_priors(&mut self, ppf: &[f64]) {
        let n = self.n;
        assert_eq!(ppf.len(), n * n, "PPF matrix must be n×n");
        if let Some(rl) = self.restrict.clone() {
            for i in 0..n {
                let start = rl.row_start(i);
                let row = &mut self.data[start..start + rl.row_len(i)];
                add_priors_to_restricted_row(&rl, i, ppf, row);
            }
            return;
        }
        let layout = self.layout().clone();
        let total = layout.total();
        for i in 0..n {
            let row = &mut self.data[i * total..(i + 1) * total];
            add_priors_to_row(&layout, i, ppf, row);
        }
    }

    /// Bytes held by the table (reporting / Fig. 6-style accounting).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Add the Eq. (9) pairwise-prior contribution to one node's dense row:
/// `row[j] += Σ_{m ∈ subset_j} PPF(node, m)`, leaving poisoned entries
/// poisoned. Shared by [`ScoreTable::add_priors`] and the hash-store
/// build (which must fold priors *before* pruning).
pub(crate) fn add_priors_to_row(layout: &SubsetLayout, node: usize, ppf: &[f64], row: &mut [f32]) {
    let n = layout.n();
    layout.for_each(|j, subset| {
        if row[j] <= NEG_SENTINEL {
            return; // keep poisoned entries poisoned
        }
        let mut add = 0f64;
        for &m in subset {
            add += ppf[node * n + m];
        }
        row[j] += add as f32;
    });
}

/// The Eq. (9) prior fold over one node's **restricted** row:
/// `row[cell] += Σ_{m ∈ subset(cell)} PPF(node, m)` with subsets decoded
/// through the node's candidate pool. Shared by the restricted dense and
/// hash builds (priors fold before pruning there too).
pub(crate) fn add_priors_to_restricted_row(
    rl: &RestrictedLayout,
    node: usize,
    ppf: &[f64],
    row: &mut [f32],
) {
    let n = rl.n();
    rl.for_each_row(node, |cell, subset| {
        if row[cell] <= NEG_SENTINEL {
            return; // keep poisoned entries poisoned
        }
        let mut add = 0f64;
        for &m in subset {
            add += ppf[node * n + m];
        }
        row[cell] += add as f32;
    });
}

/// The subset grid a tile lives in: either the shared dense layout
/// (universe = all n nodes, self-subsets poisoned) or a node's
/// candidate-pool layout (universe = the pool, never contains the node).
/// Unifies the previously duplicated dense/pool DFS fillers.
pub(crate) enum Grid<'g> {
    Full(&'g SubsetLayout),
    Restricted(&'g RestrictedLayout),
}

impl<'g> Grid<'g> {
    /// Max DFS depth any node's row can need (builder sizing).
    fn s_build(&self) -> usize {
        match self {
            Grid::Full(layout) => layout.s(),
            Grid::Restricted(rl) => rl.s(),
        }
    }

    /// The subset layout governing `node`'s row (dense: the shared
    /// layout; restricted: the node's pool-local layout with its
    /// pool-clamped `s`).
    fn node_layout(&self, node: usize) -> &'g SubsetLayout {
        match self {
            Grid::Full(layout) => layout,
            Grid::Restricted(rl) => rl.local(node),
        }
    }

    /// The DFS candidate universe for `node`'s row.
    fn uni(&self, node: usize) -> Uni<'g> {
        match self {
            Grid::Full(layout) => Uni::Full { n: layout.n(), node },
            Grid::Restricted(rl) => Uni::Pool { pool: rl.pool(node) },
        }
    }

    /// Decode the subset (global node ids) at row-local index `idx`.
    fn subset_of<'b>(&self, node: usize, idx: usize, buf: &'b mut [usize]) -> &'b [usize] {
        match self {
            Grid::Full(layout) => layout.subset_of(idx, buf),
            Grid::Restricted(rl) => rl.subset_of(node, idx, buf),
        }
    }
}

/// DFS candidate universe: positions map to global node ids, and dense
/// universes contain the node itself (those branches are poisoned).
enum Uni<'g> {
    Full { n: usize, node: usize },
    Pool { pool: &'g [usize] },
}

impl Uni<'_> {
    #[inline]
    fn size(&self) -> usize {
        match self {
            Uni::Full { n, .. } => *n,
            Uni::Pool { pool } => pool.len(),
        }
    }

    #[inline]
    fn gid(&self, pos: usize) -> usize {
        match self {
            Uni::Full { .. } => pos,
            Uni::Pool { pool } => pool[pos],
        }
    }

    #[inline]
    fn is_node(&self, pos: usize) -> bool {
        match self {
            Uni::Full { node, .. } => pos == *node,
            Uni::Pool { .. } => false,
        }
    }
}

/// What the DFS does at each leaf: score it into the tile slice, or
/// accumulate its chunk-window counts into a partial histogram (the
/// chunked path's phase 1).
enum Sink<'o> {
    Score { out: &'o mut [f32] },
    Accumulate { hist: &'o mut [u32], leaves: &'o [LeafPlan] },
}

/// Per-leaf layout of a tile's histogram bank (chunked path).
#[derive(Debug, Clone)]
pub(crate) struct LeafPlan {
    /// Cell offset of this leaf's `q · r_i` histogram in the bank.
    off: u64,
    /// Joint parent-config count; `0` marks a poisoned (self-parent)
    /// leaf with no histogram.
    q: u32,
    /// Parent-set size.
    k: u8,
    /// Sorted-ascending global parent ids — the count-cache key of this
    /// leaf's histogram. Empty for poisoned leaves.
    parents: Box<[u16]>,
}

/// Histogram-bank layout for one tile of the chunked path.
pub(crate) struct WindowPlan {
    leaves: Vec<LeafPlan>,
    cells: u64,
}

/// Per-tile histogram-bank ceiling for the chunked path; tiles whose
/// leaf histograms would exceed this fall back to the classic
/// whole-column fill (zeroing/merging a huge bank per chunk would cost
/// more than it saves).
const CHUNK_TILE_CELLS: u64 = 1 << 20;

/// Lay out the histogram bank for `node`'s row-local cells `[lo, hi)`,
/// or `None` if any leaf is too wide for dense counting (`q` beyond u32
/// or `q · r_i` beyond the dense limit) or the bank would exceed
/// [`CHUNK_TILE_CELLS`] — those tiles take the classic path instead.
pub(crate) fn plan_window(
    data: &Dataset,
    grid: &Grid,
    node: usize,
    lo: usize,
    hi: usize,
) -> Option<WindowPlan> {
    let r_i = data.arity(node);
    let mut buf = vec![0usize; grid.s_build() + 1];
    let mut leaves = Vec::with_capacity(hi - lo);
    let mut cells = 0u64;
    for idx in lo..hi {
        let subset = grid.subset_of(node, idx, &mut buf);
        if matches!(grid, Grid::Full(_)) && subset.contains(&node) {
            leaves.push(LeafPlan { off: 0, q: 0, k: 0, parents: Box::default() });
            continue;
        }
        let q: u128 =
            subset.iter().map(|&m| data.arity(m) as u128).product::<u128>().max(1);
        if q > u32::MAX as u128 || q * r_i as u128 > DENSE_LIMIT as u128 {
            return None;
        }
        leaves.push(LeafPlan {
            off: cells,
            q: q as u32,
            k: subset.len() as u8,
            parents: subset.iter().map(|&m| m as u16).collect(),
        });
        cells += q as u64 * r_i as u64;
        if cells > CHUNK_TILE_CELLS {
            return None;
        }
    }
    Some(WindowPlan { leaves, cells })
}

/// Dispatch pre-split tile slices across `exec`, filling each tile's
/// cells `[start, end)` of its node's row — the shared fill kernel of
/// the dense and hash builds, over either grid flavor.
///
/// Hot path of preprocessing (millions of local scores at n=60). Instead
/// of re-encoding parent configurations from scratch per subset
/// (O(k·rows) each), subsets are enumerated as a lexicographic DFS where
/// the [`PrefixCounter`] maintains the partial mixed-radix codes of each
/// tree level — one O(rows) update per tree edge, one O(rows) counting
/// pass per leaf (≈2 row passes per subset instead of k+1). Lexicographic
/// DFS order == layout order, so the row index is a running counter;
/// branches containing the node itself — and branches entirely outside
/// the tile's window — are skipped wholesale with a binomial jump, so a
/// tile pays only O(depth · rows) to seek to its first cell. Every cell
/// value is a pure function of `(node, subset)`, independent of the tile
/// boundaries that computed it.
///
/// Builders (with their lgamma tables and scratch buffers) live in
/// per-worker lanes, created lazily and reused across all the tiles a
/// worker claims — builder state never leaks into cell values, so the
/// reuse is invisible to the output.
pub(crate) fn fill_tiles(
    data: &Dataset,
    params: BdeParams,
    grid: &Grid,
    exec: &dyn KernelExecutor,
    tiles: &[Tile],
    slices: &[std::sync::Mutex<&mut [f32]>],
    counting: &CountingConfig,
) -> DispatchStats {
    debug_assert_eq!(tiles.len(), slices.len());
    let s_build = grid.s_build();
    let lanes: Vec<std::sync::Mutex<Option<FastRowBuilder>>> =
        (0..exec.threads().max(1)).map(|_| std::sync::Mutex::new(None)).collect();
    let lanes_ref = &lanes;
    let kernel = move |worker: usize, i: usize| {
        let t = tiles[i];
        let mut lane = lanes_ref[worker].lock().expect("builder lane poisoned");
        let builder =
            lane.get_or_insert_with(|| FastRowBuilder::new(data, params, s_build, counting));
        let mut guard = slices[i].lock().expect("tile slice poisoned");
        builder.fill_grid_range(grid, t.node, t.start, t.end, &mut guard);
    };
    let stats = exec.dispatch_timed(tiles.len(), &kernel);
    let cells: u64 = tiles.iter().map(|t| t.cells() as u64).sum();
    crate::telemetry::metrics::counting().cells.with(&[counting.mode.name()]).add(cells);
    stats
}

/// Row-chunked fill for large datasets: phase 1 fans `tiles × chunks`
/// tasks across the executor, each DFS-walking its tile over one row
/// chunk (via [`Dataset::chunks`]) and accumulating a *private* partial
/// histogram that merges into the tile's bank under a short lock; phase 2
/// scores each tile from its merged bank. u32 histogram adds commute, so
/// the merged counts — and therefore every emitted score — are
/// bit-identical to the unchunked prefix path and the naive path for any
/// chunk size, thread count, or schedule. Tiles the planner declines
/// (oversized banks, sparse-path leaves) fall back to the classic fill in
/// phase 2.
///
/// Count-cache integration works at tile granularity: a tile whose live
/// leaves are *all* resident in the cache skips phase 1 entirely and
/// copies the cached histograms into its bank (the daemon's warm-rebuild
/// fast path); a tile that had to count offers its finished bank slices
/// to the cache after phase 2. Cached counts are the exact u32 sums the
/// cold path produces, so scores stay bit-identical warm or cold.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fill_tiles_chunked(
    data: &Dataset,
    params: BdeParams,
    grid: &Grid,
    exec: &dyn KernelExecutor,
    tiles: &[Tile],
    slices: &[std::sync::Mutex<&mut [f32]>],
    counting: &CountingConfig,
    chunk_rows: usize,
) -> DispatchStats {
    debug_assert_eq!(tiles.len(), slices.len());
    debug_assert_eq!(counting.mode, CountingMode::Prefix, "only the prefix engine chunks");
    let cache = counting.cache.as_ref().filter(|cr| cr.cache.admits(data.rows()));
    let chunks: Vec<std::ops::Range<usize>> = data.chunks(chunk_rows).collect();
    let n_chunks = chunks.len().max(1);
    let plans: Vec<Option<WindowPlan>> =
        tiles.iter().map(|t| plan_window(data, grid, t.node, t.start, t.end)).collect();
    // Cache probe: `Some(hists)` when every live leaf of the tile is
    // resident (hists in leaf order, poisoned leaves skipped).
    let cached: Vec<Option<Vec<Arc<Vec<u32>>>>> = plans
        .iter()
        .zip(tiles)
        .map(|(plan, t)| {
            let (cr, plan) = (cache?, plan.as_ref()?);
            let mut hists = Vec::new();
            for lp in &plan.leaves {
                if lp.q == 0 {
                    continue;
                }
                hists.push(cr.cache.lookup(cr.dataset_key, t.node, &lp.parents)?);
            }
            Some(hists)
        })
        .collect();
    let banks: Vec<std::sync::Mutex<Vec<u32>>> = plans
        .iter()
        .map(|p| {
            let bank = p.as_ref().map(|p| vec![0u32; p.cells as usize]).unwrap_or_default();
            std::sync::Mutex::new(bank)
        })
        .collect();
    let s_build = grid.s_build();
    let lanes: Vec<std::sync::Mutex<Option<FastRowBuilder>>> =
        (0..exec.threads().max(1)).map(|_| std::sync::Mutex::new(None)).collect();
    let lanes_ref = &lanes;
    let plans_ref = &plans;
    let cached_ref = &cached;
    let banks_ref = &banks;
    let chunks_ref = &chunks;

    // Phase 1: partial-histogram accumulation over (tile × chunk) tasks.
    let accumulate = move |worker: usize, task: usize| {
        let ti = task / n_chunks;
        let plan = match &plans_ref[ti] {
            Some(p) => p,
            None => return, // classic fallback handles this tile in phase 2
        };
        if cached_ref[ti].is_some() {
            return; // fully cached: phase 2 scores straight from the cache
        }
        let chunk = chunks_ref[task % n_chunks].clone();
        let t = tiles[ti];
        let mut lane = lanes_ref[worker].lock().expect("builder lane poisoned");
        let builder =
            lane.get_or_insert_with(|| FastRowBuilder::new(data, params, s_build, counting));
        builder.accumulate_chunk(grid, t.node, t.start, t.end, plan, chunk.start, chunk.end);
        let cells = plan.cells as usize;
        let mut bank = banks_ref[ti].lock().expect("histogram bank poisoned");
        for (b, &h) in bank.iter_mut().zip(&builder.hist[..cells]) {
            *b += h;
        }
        crate::telemetry::metrics::counting().chunk_merges.inc();
    };
    let mut stats = exec.dispatch_timed(tiles.len() * n_chunks, &accumulate);

    // Phase 2: score each tile from its merged bank.
    let score = move |worker: usize, ti: usize| {
        let t = tiles[ti];
        let mut lane = lanes_ref[worker].lock().expect("builder lane poisoned");
        let builder =
            lane.get_or_insert_with(|| FastRowBuilder::new(data, params, s_build, counting));
        let mut guard = slices[ti].lock().expect("tile slice poisoned");
        match &plans_ref[ti] {
            Some(plan) => {
                let mut bank = banks_ref[ti].lock().expect("histogram bank poisoned");
                let r_i = data.arity(t.node);
                match &cached_ref[ti] {
                    Some(hists) => {
                        // Replay cached histograms into the bank at their
                        // planned offsets; scoring below is then exactly
                        // the cold path over identical counts.
                        let mut next = hists.iter();
                        for lp in &plan.leaves {
                            if lp.q == 0 {
                                continue;
                            }
                            let base = lp.off as usize;
                            let cells = lp.q as usize * r_i;
                            let h = next.next().expect("cached tile short a histogram");
                            bank[base..base + cells].copy_from_slice(h);
                        }
                    }
                    None => {
                        if let Some(cr) = cache {
                            for lp in &plan.leaves {
                                if lp.q == 0 {
                                    continue;
                                }
                                let base = lp.off as usize;
                                let cells = lp.q as usize * r_i;
                                cr.cache.insert(
                                    cr.dataset_key,
                                    t.node,
                                    &lp.parents,
                                    Arc::new(bank[base..base + cells].to_vec()),
                                );
                            }
                        }
                    }
                }
                builder.score_window_from_hist(t.node, plan, &bank, &mut guard);
            }
            None => builder.fill_grid_range(grid, t.node, t.start, t.end, &mut guard),
        }
    };
    stats.merge(&exec.dispatch_timed(tiles.len(), &score));
    let cells: u64 = tiles.iter().map(|t| t.cells() as u64).sum();
    crate::telemetry::metrics::counting().cells.with(&[counting.mode.name()]).add(cells);
    stats
}

/// Per-leaf Dirichlet-prior constants of Eq. (4), fixed by `(prior, r_i,
/// q_i)`. Computed once per leaf so the per-config fold is identical
/// across the naive, prefix, and chunked paths.
struct LeafMath {
    k2: bool,
    alpha_ik: f64,
    alpha_ijk: f64,
    lg_alpha_ik: f64,
    lg_alpha_ijk: f64,
}

fn leaf_math(params: &BdeParams, r_i: usize, q_f64: f64) -> LeafMath {
    match params.prior {
        crate::score::bde::DirichletPrior::K2 => LeafMath {
            k2: true,
            alpha_ik: 0.0,
            alpha_ijk: 0.0,
            lg_alpha_ik: 0.0,
            lg_alpha_ijk: 0.0,
        },
        crate::score::bde::DirichletPrior::BDeu { ess } => {
            let alpha_ijk = ess / (q_f64 * r_i as f64);
            let alpha_ik = ess / q_f64;
            LeafMath {
                k2: false,
                alpha_ik,
                alpha_ijk,
                lg_alpha_ik: log10_gamma(alpha_ik),
                lg_alpha_ijk: log10_gamma(alpha_ijk),
            }
        }
    }
}

/// Fold one observed parent configuration into the Eq. (4) accumulator.
/// This is the *single* scoring kernel shared by every counting path —
/// identical op order is what keeps `--counting naive|prefix` and the
/// chunked mode bit-identical.
#[inline]
fn fold_config(
    lg_int: &[f64],
    r_i: usize,
    math: &LeafMath,
    n_ik: u32,
    counts: &[u32],
    acc: &mut f64,
) {
    if math.k2 {
        // Integer fast path: α_ijk = 1, α_ik = r_i — every lgamma
        // argument is an integer, served from the lg_int table.
        *acc += lg_int[r_i] - lg_int[r_i + n_ik as usize];
        for &c in counts {
            // log10 Γ(c+1) − log10 Γ(1); Γ(1) term is 0.
            *acc += lg_int[c as usize + 1];
        }
    } else {
        *acc += math.lg_alpha_ik - log10_gamma(math.alpha_ik + n_ik as f64);
        for &c in counts {
            if c > 0 {
                *acc += log10_gamma(c as f64 + math.alpha_ijk) - math.lg_alpha_ijk;
            }
        }
    }
}

/// DFS-based row filler (see [`fill_tiles`]).
struct FastRowBuilder<'a> {
    data: &'a crate::data::Dataset,
    params: BdeParams,
    /// Engine selection: prefix-cached codes vs naive per-leaf re-encode.
    mode: CountingMode,
    /// Prefix-cached config codes aligned with the DFS stack.
    pc: PrefixCounter,
    /// Global ids of the DFS path's chosen parents (the naive path and
    /// the wide/sparse fallbacks re-encode from this).
    chosen: Vec<usize>,
    /// Reference counting path (naive mode; sparse/wide fallback in
    /// prefix mode).
    ws: CountsWorkspace,
    /// Private partial histogram for the chunked path (merged into the
    /// tile bank after each chunk task).
    hist: Vec<u32>,
    /// Cross-tile count cache, `None` when absent or when the dataset is
    /// below the cache's row threshold (the leaf-list regime).
    cache: Option<CountCacheRef>,
    log10_gamma: f64,
    /// `lg_int[m] = log10 Γ(m)` for integer m — with the K2 prior every
    /// lgamma argument in Eq. (4) is an integer bounded by rows + max
    /// arity, so the whole scoring loop becomes table lookups (the
    /// Lanczos series was ~70% of preprocessing time before this).
    lg_int: Vec<f64>,
}

impl<'a> FastRowBuilder<'a> {
    fn new(
        data: &'a crate::data::Dataset,
        params: BdeParams,
        s: usize,
        counting: &CountingConfig,
    ) -> Self {
        let rows = data.rows();
        let r_max = (0..data.cols()).map(|i| data.arity(i)).max().unwrap_or(2);
        let lg_max = rows + r_max + 2;
        let mut lg_int = Vec::with_capacity(lg_max + 1);
        lg_int.push(f64::INFINITY); // Γ(0) pole — never queried
        // lgΓ(m+1) = lgΓ(m) + log10(m): exact recurrence, no series error.
        lg_int.push(0.0); // Γ(1)
        for m in 1..lg_max {
            let last = *lg_int.last().unwrap();
            lg_int.push(last + (m as f64).log10());
        }
        let cache = counting.cache.clone().filter(|cr| cr.cache.admits(rows));
        FastRowBuilder {
            data,
            params,
            mode: counting.mode,
            pc: PrefixCounter::new(s),
            chosen: Vec::with_capacity(s + 1),
            ws: CountsWorkspace::new(),
            hist: Vec::new(),
            cache,
            log10_gamma: params.gamma.log10(),
            lg_int,
        }
    }

    /// Fill the row-local index window `[lo, hi)` of `node`'s row into
    /// `out` (`out.len() == hi - lo`) over whole columns. Blocks and DFS
    /// branches fully outside the window are skipped with their binomial
    /// leaf counts; cells inside are computed exactly as a full-row fill
    /// would.
    pub(crate) fn fill_grid_range(
        &mut self,
        grid: &Grid,
        node: usize,
        lo: usize,
        hi: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), hi - lo);
        self.pc.set_window(0, self.data.rows());
        self.chosen.clear();
        let mut sink = Sink::Score { out };
        self.walk(grid, node, lo, hi, &mut sink);
    }

    /// Chunked phase 1: accumulate `node`'s cells `[lo, hi)` over data
    /// rows `[clo, chi)` into the private `hist` partial (zeroed here;
    /// caller merges it into the tile bank).
    fn accumulate_chunk(
        &mut self,
        grid: &Grid,
        node: usize,
        lo: usize,
        hi: usize,
        plan: &WindowPlan,
        clo: usize,
        chi: usize,
    ) {
        debug_assert_eq!(self.mode, CountingMode::Prefix);
        let cells = plan.cells as usize;
        if self.hist.len() < cells {
            self.hist.resize(cells, 0);
        }
        self.hist[..cells].iter_mut().for_each(|c| *c = 0);
        self.pc.set_window(clo, chi);
        self.chosen.clear();
        let mut hist = std::mem::take(&mut self.hist);
        {
            let mut sink = Sink::Accumulate { hist: &mut hist[..cells], leaves: &plan.leaves };
            self.walk(grid, node, lo, hi, &mut sink);
        }
        self.hist = hist;
    }

    /// Chunked phase 2: score every leaf of the plan from the merged
    /// histogram bank. The per-config scan runs in ascending code order
    /// skipping unobserved configs — exactly the emission order of the
    /// unchunked counting paths, so the f64 fold is bit-identical.
    fn score_window_from_hist(
        &mut self,
        node: usize,
        plan: &WindowPlan,
        hist: &[u32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), plan.leaves.len());
        let r_i = self.data.arity(node);
        for (j, lp) in plan.leaves.iter().enumerate() {
            if lp.q == 0 {
                out[j] = NEG_SENTINEL;
                continue;
            }
            let q = lp.q as usize;
            let math = leaf_math(&self.params, r_i, q as f64);
            let mut acc = lp.k as f64 * self.log10_gamma;
            let base = lp.off as usize;
            for code in 0..q {
                let counts = &hist[base + code * r_i..base + (code + 1) * r_i];
                let n_ik: u32 = counts.iter().sum();
                if n_ik == 0 {
                    continue;
                }
                fold_config(&self.lg_int, r_i, &math, n_ik, counts, &mut acc);
            }
            out[j] = acc as f32;
        }
    }

    /// Size-block loop shared by both grid flavors: sizes run s, s−1, …,
    /// 0 (layout order), with whole blocks outside `[lo, hi)` skipped by
    /// their binomial counts.
    fn walk(&mut self, grid: &Grid, node: usize, lo: usize, hi: usize, sink: &mut Sink) {
        let layout = grid.node_layout(node);
        let uni = grid.uni(node);
        let s = layout.s();
        let bt = layout.binomials();
        let size = uni.size();
        let mut idx = 0usize;
        for d in 0..=s {
            let k = s - d;
            if k > size {
                continue;
            }
            if idx >= hi {
                break;
            }
            if k == 0 {
                if idx >= lo {
                    self.leaf(node, 0, lo, sink, &mut idx);
                } else {
                    idx += 1;
                }
                continue;
            }
            let block = bt.c(size, k) as usize;
            if idx + block <= lo {
                idx += block; // whole size block precedes the window
                continue;
            }
            self.dfs(bt, &uni, node, k, 1, 0, lo, hi, sink, &mut idx);
        }
        debug_assert!(idx >= hi);
    }

    /// Choose the parent for `level` (1-based) from `start..`, recursing
    /// until `level == k`, acting at leaves inside `[lo, hi)`. `idx`
    /// tracks the row-local layout index (lexicographic DFS == layout
    /// order within the size block).
    #[allow(clippy::too_many_arguments)]
    fn dfs(
        &mut self,
        bt: &BinomialTable,
        uni: &Uni,
        node: usize,
        k: usize,
        level: usize,
        start: usize,
        lo: usize,
        hi: usize,
        sink: &mut Sink,
        idx: &mut usize,
    ) {
        let size = uni.size();
        // Candidates at this level: start ..= size - (k - level + 1).
        for cand in start..=(size - (k - level + 1)) {
            if *idx >= hi {
                return; // rest of this subtree is past the window
            }
            let completions = bt.c(size - cand - 1, k - level) as usize;
            if *idx + completions <= lo {
                // Entire branch precedes the window — binomial jump, no
                // code extension needed.
                *idx += completions;
                continue;
            }
            if uni.is_node(cand) {
                // Every subset under this branch contains `node` —
                // poison the in-window part (histogram plans mark these
                // leaves q = 0; the accumulator just jumps them).
                let a = (*idx).max(lo);
                let b = (*idx + completions).min(hi);
                if a < b {
                    if let Sink::Score { out } = sink {
                        out[a - lo..b - lo].fill(NEG_SENTINEL);
                    }
                }
                *idx += completions;
                continue;
            }
            let gid = uni.gid(cand);
            let arity = self.data.arity(gid);
            if self.mode == CountingMode::Prefix {
                // A failed push (u32 overflow) flags the depth; affected
                // leaves detect it via their arity product and take the
                // naive fallback.
                self.pc.push_level(level - 1, self.data.column(gid), arity);
            }
            self.chosen.push(gid);
            if level == k {
                // completions == 1 and the guards above put idx in
                // [lo, hi), so this leaf is in the window.
                self.leaf(node, k, lo, sink, idx);
            } else {
                self.dfs(bt, uni, node, k, level + 1, cand + 1, lo, hi, sink, idx);
            }
            self.chosen.pop();
        }
    }

    /// Act on the leaf at `*idx` (guaranteed in-window): score it or
    /// accumulate its chunk counts. Advances `idx`.
    fn leaf(&mut self, node: usize, k: usize, lo: usize, sink: &mut Sink, idx: &mut usize) {
        match sink {
            Sink::Score { out } => {
                out[*idx - lo] = self.score_leaf(node, k) as f32;
            }
            Sink::Accumulate { hist, leaves } => {
                let lp = &leaves[*idx - lo];
                debug_assert!(lp.q > 0, "accumulate reached a poisoned leaf");
                let r_i = self.data.arity(node);
                let base = lp.off as usize;
                let cells = lp.q as usize * r_i;
                self.pc.accumulate_window(
                    k,
                    self.data.column(node),
                    r_i,
                    &mut hist[base..base + cells],
                );
            }
        }
        *idx += 1;
    }

    /// Exhaustive bitmask mode: score **all** subsets of
    /// `{0..n-1} \ {node}` (up to n−1 parents) into `row[bitmask]`.
    /// Caller pre-poisons the row.
    fn fill_masks(&mut self, n: usize, node: usize, row: &mut [f32]) {
        self.pc.set_window(0, self.data.rows());
        self.chosen.clear();
        row[0] = self.score_leaf(node, 0) as f32;
        self.dfs_masks(n, node, 1, 0, 0, row);
    }

    /// DFS body of [`Self::fill_masks`]: every DFS node *is* a subset —
    /// score it, then extend.
    fn dfs_masks(
        &mut self,
        n: usize,
        node: usize,
        level: usize,
        start: usize,
        mask: usize,
        row: &mut [f32],
    ) {
        for cand in start..n {
            if cand == node {
                continue;
            }
            let arity = self.data.arity(cand);
            if self.mode == CountingMode::Prefix {
                self.pc.push_level(level - 1, self.data.column(cand), arity);
            }
            self.chosen.push(cand);
            let new_mask = mask | (1 << cand);
            row[new_mask] = self.score_leaf(node, level) as f32;
            self.dfs_masks(n, node, level + 1, cand + 1, new_mask, row);
            self.chosen.pop();
        }
    }

    /// Equation (4) at a leaf: counts over the chosen parent set, folded
    /// through [`fold_config`]. Prefix mode counts from the cached
    /// depth-`k` codes; naive mode — and prefix leaves that outgrew the
    /// dense/u32 envelope — re-encode through the reference
    /// [`CountsWorkspace`] (both engines share the sparse path, keeping
    /// them bit-identical there too).
    fn score_leaf(&mut self, node: usize, k: usize) -> f64 {
        let FastRowBuilder { data, params, mode, pc, ws, chosen, lg_int, log10_gamma, cache, .. } =
            self;
        let data: &Dataset = data;
        let lg_int: &[f64] = lg_int;
        let r_i = data.arity(node);
        let q_wide: u128 =
            chosen.iter().map(|&m| data.arity(m) as u128).product::<u128>().max(1);
        let math = leaf_math(params, r_i, q_wide as f64);
        let mut acc = k as f64 * *log10_gamma;
        let dense_ok = q_wide <= u32::MAX as u128
            && (q_wide as u64).saturating_mul(r_i as u64) <= DENSE_LIMIT as u64;
        if dense_ok {
            if let Some(cr) = cache {
                // Cache route: materialize (or fetch) the full dense
                // histogram and fold it in ascending code order skipping
                // unobserved configs — the exact emission order of both
                // uncached engines below, so the score is bit-identical.
                let q = q_wide as usize;
                let parents: Vec<u16> = chosen.iter().map(|&m| m as u16).collect();
                let hist = match cr.cache.lookup(cr.dataset_key, node, &parents) {
                    Some(hist) => hist,
                    None => {
                        let mut fresh = vec![0u32; q * r_i];
                        if *mode == CountingMode::Prefix {
                            debug_assert_eq!(pc.q_at(k), Some(q));
                            pc.accumulate_window(k, data.column(node), r_i, &mut fresh);
                        } else {
                            ws.accumulate_dense(data, node, chosen, &mut fresh);
                        }
                        let fresh = Arc::new(fresh);
                        cr.cache.insert(cr.dataset_key, node, &parents, fresh.clone());
                        fresh
                    }
                };
                for code in 0..q {
                    let counts = &hist[code * r_i..(code + 1) * r_i];
                    let n_ik: u32 = counts.iter().sum();
                    if n_ik == 0 {
                        continue;
                    }
                    fold_config(lg_int, r_i, &math, n_ik, counts, &mut acc);
                }
                return acc;
            }
        }
        if *mode == CountingMode::Prefix && dense_ok {
            debug_assert_eq!(pc.q_at(k), Some(q_wide as usize));
            pc.count_window(k, data.column(node), r_i, |n_ik, counts| {
                fold_config(lg_int, r_i, &math, n_ik, counts, &mut acc)
            });
        } else {
            ws.for_each_config(data, node, chosen, |n_ik, counts| {
                fold_config(lg_int, r_i, &math, n_ik, counts, &mut acc)
            });
        }
        acc
    }
}

/// Exhaustive bitmask-indexed table: `ls(i, π)` for **every** subset π of
/// the other nodes (the paper's "all possible parent sets" configuration).
pub struct FullScoreTable {
    n: usize,
    /// `data[i << n | mask]`, mask over all n bits; entries with bit i set
    /// are poisoned.
    data: Vec<f32>,
}

impl FullScoreTable {
    /// Hard cap — 2^n·n f32 grows fast; 16 nodes = 4 MB, 20 = 80 MB
    /// (20 is the paper's own Table V ceiling — it skipped the 37-node
    /// network for exactly this blowup).
    pub const MAX_N: usize = 20;

    /// Build the exhaustive table (single-threaded nodes × parallel level
    /// is unnecessary at these sizes; still threaded per node for parity).
    pub fn build(data: &Dataset, params: BdeParams, threads: usize) -> Self {
        let n = data.cols();
        assert!(n <= Self::MAX_N, "FullScoreTable limited to {} nodes", Self::MAX_N);
        let size = 1usize << n;
        let mut table = vec![0f32; n * size];
        let threads = threads.max(1).min(n.max(1));
        let mut buckets: Vec<Vec<(usize, &mut [f32])>> = (0..threads).map(|_| Vec::new()).collect();
        for (i, row) in table.chunks_mut(size).enumerate() {
            buckets[i % threads].push((i, row));
        }
        // Fast path only when the largest contingency table stays dense:
        // q·r = Π arities (≈ full joint). Binary 20-node: 2 MB — fine;
        // 3-state 20-node: 3^20 — falls back to the sparse LocalScorer.
        let joint: u128 = (0..n).map(|i| data.arity(i) as u128).product();
        let dense_ok = joint <= (1u128 << 24);
        std::thread::scope(|scope| {
            for mine in buckets {
                scope.spawn(move || {
                    if dense_ok {
                        let mut builder = FastRowBuilder::new(
                            data,
                            params,
                            n.saturating_sub(1),
                            &CountingConfig::prefix(),
                        );
                        for (i, row) in mine {
                            row.fill(NEG_SENTINEL);
                            builder.fill_masks(n, i, row);
                        }
                    } else {
                        let mut scorer = LocalScorer::new(data, params);
                        let mut parents = Vec::with_capacity(n);
                        for (i, row) in mine {
                            for mask in 0usize..size {
                                if mask & (1 << i) != 0 {
                                    row[mask] = NEG_SENTINEL;
                                    continue;
                                }
                                parents.clear();
                                let mut m = mask;
                                while m != 0 {
                                    let b = m.trailing_zeros() as usize;
                                    parents.push(b);
                                    m &= m - 1;
                                }
                                row[mask] = scorer.score(i, &parents) as f32;
                            }
                        }
                    }
                });
            }
        });
        FullScoreTable { n, data: table }
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Score of `node` with parent-set bitmask `mask`.
    #[inline]
    pub fn get(&self, node: usize, mask: usize) -> f32 {
        self.data[(node << self.n) | mask]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::sampling::forward_sample;
    use crate::bn::Network;
    use crate::util::Pcg32;

    fn small_data(n: usize, rows: usize, seed: u64) -> Dataset {
        let mut rng = Pcg32::new(seed);
        let dag = crate::bn::random::random_dag(n, 2, n, &mut rng);
        let net = Network::with_random_cpts(dag, vec![2; n], &mut rng);
        forward_sample(&net, rows, &mut rng)
    }

    #[test]
    fn table_matches_direct_scoring() {
        let data = small_data(6, 150, 41);
        let params = BdeParams::default();
        let table = ScoreTable::build(&data, params, 3, 2);
        let mut scorer = LocalScorer::new(&data, params);
        let layout = table.layout().clone();
        for i in 0..6usize {
            layout.for_each(|idx, subset| {
                let got = table.get(i, idx);
                if subset.contains(&i) {
                    assert_eq!(got, NEG_SENTINEL);
                } else {
                    let want = scorer.score(i, subset) as f32;
                    assert!((got - want).abs() < 1e-5, "i={i} subset={subset:?}");
                }
            });
        }
    }

    #[test]
    fn threading_is_deterministic() {
        let data = small_data(7, 100, 42);
        let t1 = ScoreTable::build(&data, BdeParams::default(), 3, 1);
        let t4 = ScoreTable::build(&data, BdeParams::default(), 3, 4);
        assert_eq!(t1.raw(), t4.raw());
    }

    /// Every (threads, schedule, tile) configuration produces the exact
    /// bytes of the serial build — scheduling moves work, never values.
    #[test]
    fn tiled_builds_are_bit_identical() {
        use crate::exec::{ExecConfig, Schedule};
        let data = small_data(6, 120, 47);
        let params = BdeParams::default();
        let reference = ScoreTable::build(&data, params, 3, 1);
        for threads in [1usize, 2, 8] {
            for schedule in [Schedule::Static, Schedule::Balanced] {
                for tile in [0usize, 1, 7, 64, 10_000] {
                    let cfg = ExecConfig::new(threads, schedule, tile);
                    let table = ScoreTable::build_with(&data, params, 3, &cfg);
                    assert_eq!(
                        reference.raw(),
                        table.raw(),
                        "threads={threads} schedule={schedule:?} tile={tile}"
                    );
                }
            }
        }
    }

    /// The counting-engine toggle never changes a byte: naive re-encode,
    /// unchunked prefix, and chunked prefix (several chunk sizes) all
    /// emit identical stores, dense and restricted.
    #[test]
    fn counting_modes_are_bit_identical() {
        use crate::combinatorics::RestrictedLayout;
        let data = small_data(6, 130, 52);
        let params = BdeParams::default();
        let cfg = ExecConfig::balanced(3);
        let naive =
            ScoreTable::build_counted_with(&data, params, 3, &cfg, &CountingConfig::naive()).0;
        let prefix =
            ScoreTable::build_counted_with(&data, params, 3, &cfg, &CountingConfig::prefix()).0;
        assert_eq!(naive.raw(), prefix.raw());
        for chunk_rows in [16usize, 64, 129] {
            let chunked = CountingConfig { chunk_rows, ..CountingConfig::prefix() };
            let table = ScoreTable::build_counted_with(&data, params, 3, &cfg, &chunked).0;
            assert_eq!(naive.raw(), table.raw(), "chunk_rows={chunk_rows}");
        }
        let rl = std::sync::Arc::new(RestrictedLayout::full_pools(6, 3));
        let rnaive = ScoreTable::build_restricted_counted_with(
            &data,
            params,
            &rl,
            &cfg,
            &CountingConfig::naive(),
        )
        .0;
        let rprefix = ScoreTable::build_restricted_counted_with(
            &data,
            params,
            &rl,
            &cfg,
            &CountingConfig::prefix(),
        )
        .0;
        assert_eq!(rnaive.raw(), rprefix.raw());
        let chunked = CountingConfig { chunk_rows: 32, ..CountingConfig::prefix() };
        let rchunked =
            ScoreTable::build_restricted_counted_with(&data, params, &rl, &cfg, &chunked).0;
        assert_eq!(rnaive.raw(), rchunked.raw());
    }

    /// The count cache never changes a byte: cold cache, warm cache,
    /// and both counting modes sharing one cache all reproduce the
    /// uncached table exactly — including the chunked path, whose
    /// fully-cached tiles skip phase 1 and score from cached hists.
    #[test]
    fn count_cache_is_bit_identical_cold_and_warm() {
        use crate::score::adcache::{CountCache, CountCacheRef};
        let data = small_data(6, 140, 54);
        let params = BdeParams::default();
        let cfg = ExecConfig::balanced(3);
        let baseline =
            ScoreTable::build_counted_with(&data, params, 3, &cfg, &CountingConfig::prefix()).0;
        let cache = Arc::new(CountCache::new(1 << 24, 0));
        let cr = CountCacheRef { cache: cache.clone(), dataset_key: 7 };
        for counting in [
            CountingConfig::prefix().with_cache(cr.clone()),
            CountingConfig::naive().with_cache(cr.clone()),
            CountingConfig { chunk_rows: 32, ..CountingConfig::prefix() }.with_cache(cr.clone()),
        ] {
            let t = ScoreTable::build_counted_with(&data, params, 3, &cfg, &counting).0;
            assert_eq!(baseline.raw(), t.raw(), "counting={counting:?}");
        }
        let s = cache.stats();
        assert!(s.insertions > 0, "cache was never populated");
        assert!(s.hits > 0, "warm rebuilds never hit");
    }

    /// Counting modes also agree under the BDeu prior (non-integer
    /// lgamma path) — the shared fold covers both priors.
    #[test]
    fn counting_modes_agree_under_bdeu() {
        use crate::score::bde::DirichletPrior;
        let data = small_data(5, 90, 53);
        let params = BdeParams { prior: DirichletPrior::BDeu { ess: 2.0 }, ..BdeParams::default() };
        let cfg = ExecConfig::balanced(2);
        let naive =
            ScoreTable::build_counted_with(&data, params, 3, &cfg, &CountingConfig::naive()).0;
        let prefix =
            ScoreTable::build_counted_with(&data, params, 3, &cfg, &CountingConfig::prefix()).0;
        assert_eq!(naive.raw(), prefix.raw());
        let chunked = CountingConfig { chunk_rows: 17, ..CountingConfig::prefix() };
        let table = ScoreTable::build_counted_with(&data, params, 3, &cfg, &chunked).0;
        assert_eq!(naive.raw(), table.raw());
    }

    /// Regression for the old `threads.max(1).min(n)` clamp: with
    /// sub-row tiles, `threads > n` builds correctly (and the tile plan
    /// actually has more work items than nodes to hand those cores).
    #[test]
    fn more_threads_than_nodes_builds_identically() {
        use crate::exec::{plan_tiles, ExecConfig, Schedule};
        let data = small_data(4, 80, 48);
        let params = BdeParams::default();
        let reference = ScoreTable::build(&data, params, 3, 1);
        let cfg = ExecConfig::new(8, Schedule::Balanced, 2);
        let tiled = ScoreTable::build_with(&data, params, 3, &cfg);
        assert_eq!(reference.raw(), tiled.raw());
        assert!(
            plan_tiles(4, reference.subsets(), 2).len() >= 8,
            "sub-row tiles must outnumber the 4 rows"
        );
    }

    /// A full-pool restriction (`k_i = n−1`) reproduces the
    /// unrestricted table bit for bit on every non-self subset, and
    /// reads the sentinel for self-containing (out-of-pool) subsets.
    #[test]
    fn restricted_full_pools_match_unrestricted_bitwise() {
        use crate::combinatorics::RestrictedLayout;
        let data = small_data(7, 130, 49);
        let params = BdeParams::default();
        let dense = ScoreTable::build(&data, params, 3, 2);
        let rl = std::sync::Arc::new(RestrictedLayout::full_pools(7, 3));
        let restricted =
            ScoreTable::build_restricted_with(&data, params, &rl, &ExecConfig::balanced(2));
        assert!(restricted.cells() < dense.cells());
        assert!(restricted.layout_opt().is_none(), "ragged table materialized a global layout");
        let layout = dense.layout().clone();
        for i in 0..7usize {
            layout.for_each(|idx, subset| {
                let want = dense.get(i, idx);
                // score_of bridges the index spaces: pool resolution on
                // the ragged side (self subsets are out-of-pool and read
                // the sentinel, matching the dense table's poison).
                let got = restricted.score_of(i, subset);
                if subset.contains(&i) {
                    assert_eq!(want, NEG_SENTINEL);
                    assert_eq!(got, NEG_SENTINEL);
                } else {
                    assert_eq!(got, want, "i={i} subset={subset:?}");
                }
            });
        }
    }

    /// Restricted builds are bit-identical for any threads × schedule ×
    /// tile, and subsets outside the pools read the sentinel.
    #[test]
    fn restricted_tiled_builds_are_bit_identical() {
        use crate::combinatorics::RestrictedLayout;
        use crate::exec::Schedule;
        let data = small_data(8, 110, 50);
        let params = BdeParams::default();
        // Narrow pools: node i may only draw parents from {(i+1)%8, (i+3)%8}.
        let pools: Vec<Vec<usize>> = (0..8usize)
            .map(|i| {
                let mut p = vec![(i + 1) % 8, (i + 3) % 8];
                p.sort_unstable();
                p
            })
            .collect();
        let rl = std::sync::Arc::new(RestrictedLayout::new(8, 3, pools));
        let reference =
            ScoreTable::build_restricted_with(&data, params, &rl, &ExecConfig::balanced(1));
        for threads in [2usize, 8] {
            for schedule in [Schedule::Static, Schedule::Balanced] {
                for tile in [0usize, 1, 3, 100] {
                    let cfg = ExecConfig::new(threads, schedule, tile);
                    let tiled = ScoreTable::build_restricted_with(&data, params, &rl, &cfg);
                    assert_eq!(
                        reference.raw(),
                        tiled.raw(),
                        "threads={threads} schedule={schedule:?} tile={tile}"
                    );
                }
            }
        }
        // Out-of-pool subsets (node 0's pool is {1, 3}) read the sentinel.
        assert_eq!(reference.score_of(0, &[2]), NEG_SENTINEL);
        assert!(reference.score_of(0, &[1, 3]) > NEG_SENTINEL);
        // In-pool cells agree with a direct scorer.
        let mut scorer = LocalScorer::new(&data, params);
        assert!(
            (reference.score_of(0, &[1, 3]) - scorer.score(0, &[1, 3]) as f32).abs() < 1e-5
        );
    }

    /// Restricted prior folding shifts exactly the in-pool subsets that
    /// contain the favored parent.
    #[test]
    fn restricted_priors_shift_pool_subsets() {
        use crate::combinatorics::RestrictedLayout;
        let data = small_data(5, 80, 51);
        let params = BdeParams::default();
        let rl = std::sync::Arc::new(RestrictedLayout::full_pools(5, 2));
        let mut table =
            ScoreTable::build_restricted_with(&data, params, &rl, &ExecConfig::balanced(1));
        let before = table.raw().to_vec();
        let n = 5usize;
        let mut ppf = vec![0f64; n * n];
        ppf[2 * n] = 3.5; // edge 0 → 2 favored
        table.add_priors(&ppf);
        let mut buf = [0usize; crate::combinatorics::restricted::MAX_S];
        for i in 0..n {
            for cell in 0..rl.row_len(i) {
                let subset = rl.subset_of(i, cell, &mut buf).to_vec();
                let delta = table.get_cell(i, cell) - before[rl.row_start(i) + cell];
                if i == 2 && subset.contains(&0) {
                    assert!((delta - 3.5).abs() < 1e-5, "i={i} {subset:?}");
                } else {
                    assert_eq!(delta, 0.0, "i={i} {subset:?}");
                }
            }
        }
    }

    #[test]
    fn score_of_uses_layout_indexing() {
        let data = small_data(5, 80, 43);
        let table = ScoreTable::build(&data, BdeParams::default(), 2, 2);
        let mut scorer = LocalScorer::new(&data, BdeParams::default());
        assert!((table.score_of(0, &[1, 3]) - scorer.score(0, &[1, 3]) as f32).abs() < 1e-5);
        assert!((table.score_of(4, &[]) - scorer.score(4, &[]) as f32).abs() < 1e-5);
    }

    #[test]
    fn priors_shift_entries_by_subset_sum() {
        let data = small_data(4, 60, 44);
        let mut table = ScoreTable::build(&data, BdeParams::default(), 2, 1);
        let before = table.raw().to_vec();
        let n = 4usize;
        let mut ppf = vec![0f64; n * n];
        ppf[n] = 7.5; // PPF(1, 0) at index 1*n+0: edge 0→1 favored
        table.add_priors(&ppf);
        let layout = table.layout().clone();
        for i in 0..n {
            layout.for_each(|j, subset| {
                let delta = table.get(i, j) - before[i * layout.total() + j];
                if before[i * layout.total() + j] <= NEG_SENTINEL {
                    assert_eq!(delta, 0.0);
                } else if i == 1 && subset.contains(&0) {
                    assert!((delta - 7.5).abs() < 1e-5, "i={i} {subset:?}");
                } else {
                    assert_eq!(delta, 0.0, "i={i} {subset:?}");
                }
            });
        }
    }

    #[test]
    fn full_table_agrees_with_bounded_on_small_sets() {
        let data = small_data(5, 120, 45);
        let params = BdeParams::default();
        let bounded = ScoreTable::build(&data, params, 2, 2);
        let full = FullScoreTable::build(&data, params, 2);
        let layout = bounded.layout().clone();
        for i in 0..5usize {
            layout.for_each(|idx, subset| {
                let mask: usize = subset.iter().map(|&m| 1usize << m).sum();
                let a = bounded.get(i, idx);
                let b = full.get(i, mask);
                if subset.contains(&i) {
                    assert_eq!(a, NEG_SENTINEL);
                    assert_eq!(b, NEG_SENTINEL);
                } else {
                    assert!((a - b).abs() < 1e-6, "i={i} subset={subset:?}");
                }
            });
        }
    }

    #[test]
    fn full_table_poisons_self_parent_masks() {
        let data = small_data(4, 50, 46);
        let full = FullScoreTable::build(&data, BdeParams::default(), 1);
        for i in 0..4usize {
            for mask in 0..(1usize << 4) {
                if mask & (1 << i) != 0 {
                    assert_eq!(full.get(i, mask), NEG_SENTINEL);
                } else {
                    assert!(full.get(i, mask) > NEG_SENTINEL);
                }
            }
        }
    }
}
