//! The cross-tile count cache: AD-tree-style contingency reuse
//! *across* tiles, nodes, and whole store builds.
//!
//! PR 6's `PrefixCounter` reuses parent-config codes only along one
//! subset-DFS path; this module persists the finished product — the
//! dense `N_ijk` histogram of a `(node, parent set)` query — keyed by
//! the *dataset* half of the store fingerprint, so the same counts
//! serve every tile that needs them, every counting mode, and every
//! subsequent build over the same data (the daemon's cross-job case).
//! That is the "keep the low-order tables around" half of Scutari's
//! optimised-bnlearn observation (arXiv 1406.7648); a full AD-tree is
//! unnecessary because the DFS already enumerates queries in subset
//! order.
//!
//! Retention policy:
//! * **k ≤ 1 entries are pinned** — per-node marginals and per-pair
//!   tables are tiny (`r_i`, `r_m·r_i` cells), shared by *every*
//!   superset query's subtree, and never evicted;
//! * **k ≥ 2 entries are LRU** under the byte budget; an entry larger
//!   than the whole budget is served to its caller but never inserted;
//! * **small datasets bypass the cache entirely** (`rows < min_rows`,
//!   the leaf-list regime): below the threshold a whole-column recount
//!   is cheaper than a shared-map probe, so the builders keep their
//!   allocation-free hot path.
//!
//! Determinism: the cache stores *exact u32 counts*, and cached-hit
//! scoring folds them in ascending config-code order — the same
//! emission contract every counting path honours (DESIGN.md §14) — so
//! stores are bit-identical with the cache on or off, warm or cold.
//! Lookup keys include the dataset fingerprint, making cross-dataset
//! collisions impossible rather than unlikely.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Default shared-instance byte budget (the one-shot CLI path; the
/// daemon installs its own slice of `--cache-bytes`).
pub const DEFAULT_BUDGET: usize = 1 << 28;

/// Default row threshold below which the cache declines to engage.
pub const DEFAULT_MIN_ROWS: usize = 1 << 14;

/// Telemetry snapshot (the daemon's `stats` command serializes this).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CountCacheStats {
    /// Histogram lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to count.
    pub misses: u64,
    /// Histograms admitted.
    pub insertions: u64,
    /// LRU entries dropped to fit the byte budget.
    pub evictions: u64,
    /// Entries currently resident (pinned + LRU).
    pub entries: usize,
    /// Bytes of resident histograms.
    pub bytes: usize,
}

#[derive(Debug, PartialEq, Eq, Hash)]
struct Key {
    dataset: u64,
    node: u32,
    /// Sorted-ascending global parent column ids.
    parents: Box<[u16]>,
}

struct Entry {
    hist: Arc<Vec<u32>>,
    bytes: usize,
    last_used: u64,
    pinned: bool,
}

struct Inner {
    map: HashMap<Key, Entry>,
    clock: u64,
    bytes: usize,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
}

/// The count cache. See the module docs for the retention and
/// determinism contract.
pub struct CountCache {
    capacity: usize,
    min_rows: usize,
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for CountCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("CountCache")
            .field("capacity", &self.capacity)
            .field("min_rows", &self.min_rows)
            .field("stats", &s)
            .finish()
    }
}

/// Approximate resident cost of one entry: histogram cells plus map
/// and key overhead.
fn entry_bytes(parents: usize, cells: usize) -> usize {
    cells * std::mem::size_of::<u32>() + parents * 2 + 64
}

impl CountCache {
    /// A cache bounded to `capacity` LRU bytes, bypassed below
    /// `min_rows` rows. `capacity == 0` disables it entirely.
    pub fn new(capacity: usize, min_rows: usize) -> Self {
        let inner = Inner {
            map: HashMap::new(),
            clock: 0,
            bytes: 0,
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
        };
        CountCache { capacity, min_rows, inner: Mutex::new(inner) }
    }

    /// Whether the cache engages for a dataset of `rows` rows.
    pub fn admits(&self, rows: usize) -> bool {
        self.capacity > 0 && rows >= self.min_rows
    }

    /// Bytes currently resident (the daemon charges these against its
    /// `--cache-bytes` budget alongside the store cache).
    pub fn bytes(&self) -> usize {
        self.lock().bytes
    }

    /// Current telemetry.
    pub fn stats(&self) -> CountCacheStats {
        let inner = self.lock();
        CountCacheStats {
            hits: inner.hits,
            misses: inner.misses,
            insertions: inner.insertions,
            evictions: inner.evictions,
            entries: inner.map.len(),
            bytes: inner.bytes,
        }
    }

    /// The cached dense histogram (`hist[code·r_i + state]`) for
    /// `(dataset, node, parents)`, or `None` (counted as a miss).
    pub fn lookup(&self, dataset: u64, node: usize, parents: &[u16]) -> Option<Arc<Vec<u32>>> {
        let key = Key { dataset, node: node as u32, parents: parents.into() };
        let mut inner = self.lock();
        inner.clock += 1;
        let now = inner.clock;
        let tm = crate::telemetry::metrics::count_cache();
        match inner.map.get_mut(&key) {
            Some(entry) => {
                entry.last_used = now;
                let hist = entry.hist.clone();
                inner.hits += 1;
                tm.hits.inc();
                Some(hist)
            }
            None => {
                inner.misses += 1;
                tm.misses.inc();
                None
            }
        }
    }

    /// Admit a freshly-counted histogram. k ≤ 1 entries are pinned;
    /// larger ones evict LRU peers to fit (or are dropped when bigger
    /// than the whole budget). Re-inserting an existing key is a no-op
    /// (concurrent builders may race to the same miss — both counted
    /// the same bytes, so either copy is fine).
    pub fn insert(&self, dataset: u64, node: usize, parents: &[u16], hist: Arc<Vec<u32>>) {
        let pinned = parents.len() <= 1;
        let bytes = entry_bytes(parents.len(), hist.len());
        if !pinned && bytes > self.capacity {
            return;
        }
        let key = Key { dataset, node: node as u32, parents: parents.into() };
        let mut inner = self.lock();
        if inner.map.contains_key(&key) {
            return;
        }
        inner.clock += 1;
        let now = inner.clock;
        inner.map.insert(key, Entry { hist, bytes, last_used: now, pinned });
        inner.bytes += bytes;
        inner.insertions += 1;
        self.evict_to_fit(&mut inner);
        let tm = crate::telemetry::metrics::count_cache();
        tm.insertions.inc();
        tm.bytes.set_u64(inner.bytes as u64);
        tm.entries.set_u64(inner.map.len() as u64);
    }

    /// Evict LRU unpinned entries until the budget fits. Pinned
    /// entries never leave, so the resident floor is the (tiny)
    /// marginal + pair table set.
    fn evict_to_fit(&self, inner: &mut Inner) {
        while inner.bytes > self.capacity {
            let victim = inner
                .map
                .iter()
                .filter(|(_, e)| !e.pinned)
                .map(|(k, e)| (e.last_used, k.dataset, k.node, k.parents.clone()))
                .min();
            let Some((_, dataset, node, parents)) = victim else { break };
            if let Some(e) = inner.map.remove(&Key { dataset, node, parents }) {
                inner.bytes -= e.bytes;
                inner.evictions += 1;
                crate::telemetry::metrics::count_cache().evictions.inc();
            }
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().expect("count-cache lock poisoned")
    }
}

/// A handle attaching a cache to one dataset's builds: the cache plus
/// the dataset fingerprint its keys are scoped under
/// ([`crate::coordinator::dataset_fingerprint`]).
#[derive(Debug, Clone)]
pub struct CountCacheRef {
    /// The (usually process-shared) cache.
    pub cache: Arc<CountCache>,
    /// Dataset identity folded into every key.
    pub dataset_key: u64,
}

static SHARED: OnceLock<Arc<CountCache>> = OnceLock::new();

/// Install the process-wide shared count cache. First call wins (the
/// daemon calls this at startup with its `--cache-bytes` slice, before
/// any job runs); later calls return the installed instance.
pub fn install_shared(cache: Arc<CountCache>) -> Arc<CountCache> {
    SHARED.get_or_init(|| cache).clone()
}

/// The process-wide shared cache, creating a default-budget one on
/// first use ([`DEFAULT_BUDGET`], [`DEFAULT_MIN_ROWS`]).
pub fn shared() -> Arc<CountCache> {
    SHARED.get_or_init(|| Arc::new(CountCache::new(DEFAULT_BUDGET, DEFAULT_MIN_ROWS))).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist(cells: usize, fill: u32) -> Arc<Vec<u32>> {
        Arc::new(vec![fill; cells])
    }

    #[test]
    fn lookup_miss_then_hit() {
        let c = CountCache::new(1 << 20, 0);
        assert!(c.lookup(1, 0, &[2, 3]).is_none());
        c.insert(1, 0, &[2, 3], hist(12, 7));
        let got = c.lookup(1, 0, &[2, 3]).unwrap();
        assert_eq!(*got, vec![7u32; 12]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.insertions, s.entries), (1, 1, 1, 1));
    }

    #[test]
    fn keys_are_scoped_by_dataset_node_and_parents() {
        let c = CountCache::new(1 << 20, 0);
        c.insert(1, 0, &[2], hist(6, 1));
        assert!(c.lookup(2, 0, &[2]).is_none(), "different dataset");
        assert!(c.lookup(1, 1, &[2]).is_none(), "different node");
        assert!(c.lookup(1, 0, &[3]).is_none(), "different parents");
        assert!(c.lookup(1, 0, &[]).is_none(), "different k");
        assert!(c.lookup(1, 0, &[2]).is_some());
    }

    #[test]
    fn lru_eviction_spares_pinned_entries() {
        // Budget fits roughly two big entries.
        let big = entry_bytes(2, 1000);
        let c = CountCache::new(2 * big + big / 2, 0);
        c.insert(1, 0, &[], hist(4, 1)); // pinned marginal
        c.insert(1, 0, &[1], hist(8, 1)); // pinned pair
        c.insert(1, 0, &[1, 2], hist(1000, 1));
        c.insert(1, 0, &[1, 3], hist(1000, 1));
        // Touch the first big entry so the second is the LRU victim.
        assert!(c.lookup(1, 0, &[1, 2]).is_some());
        c.insert(1, 0, &[1, 4], hist(1000, 1));
        let s = c.stats();
        assert!(s.evictions >= 1);
        assert!(c.lookup(1, 0, &[]).is_some(), "pinned marginal survives");
        assert!(c.lookup(1, 0, &[1]).is_some(), "pinned pair survives");
        assert!(c.lookup(1, 0, &[1, 2]).is_some(), "recently-used entry survives");
        assert!(c.lookup(1, 0, &[1, 3]).is_none(), "LRU entry evicted");
    }

    #[test]
    fn oversized_unpinned_entries_are_not_admitted() {
        let c = CountCache::new(64, 0);
        c.insert(1, 0, &[1, 2], hist(1000, 1));
        assert!(c.lookup(1, 0, &[1, 2]).is_none());
        assert_eq!(c.stats().insertions, 0);
        // Pinned entries are exempt from the size gate.
        c.insert(1, 0, &[1], hist(1000, 1));
        assert!(c.lookup(1, 0, &[1]).is_some());
    }

    #[test]
    fn admits_honours_capacity_and_min_rows() {
        let c = CountCache::new(1 << 20, 1000);
        assert!(!c.admits(999));
        assert!(c.admits(1000));
        let disabled = CountCache::new(0, 0);
        assert!(!disabled.admits(1_000_000));
    }

    #[test]
    fn reinsert_is_a_noop() {
        let c = CountCache::new(1 << 20, 0);
        c.insert(1, 0, &[2], hist(6, 1));
        c.insert(1, 0, &[2], hist(6, 99));
        assert_eq!(*c.lookup(1, 0, &[2]).unwrap(), vec![1u32; 6]);
        assert_eq!(c.stats().insertions, 1);
    }
}
