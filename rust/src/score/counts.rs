//! Contingency counting: the `N_ik` / `N_ijk` statistics of Equation (3).
//!
//! For a node `i` with parent set `π`, `N_ijk` is the number of
//! observations where `v_i` is in state `j` and the parents jointly take
//! configuration `k`. Parent configurations are mixed-radix encoded
//! (first parent fastest).
//!
//! Counting is *sparse*: only configurations that actually occur are
//! materialized. Unobserved configurations contribute exactly zero to the
//! BDe score (`logΓ(α)−logΓ(α+0) = 0`), so skipping them is both the
//! correctness-preserving and the fast thing to do — with N observations
//! at most N configurations are touched regardless of `r_i = Π arities`.

use std::collections::HashMap;

use crate::data::Dataset;

/// Reusable scratch for one thread's counting loop; avoids re-allocating
/// and re-zeroing per local score (the preprocessing stage computes
/// millions of them).
#[derive(Debug)]
pub struct CountsWorkspace {
    /// Dense per-(config,state) counts, length = capacity currently held.
    dense: Vec<u32>,
    /// Configs touched this round (for O(touched) clearing).
    touched: Vec<u32>,
    /// Per-row parent config codes (reused across nodes for a fixed π).
    codes: Vec<u32>,
    /// Sparse fallback for huge config spaces (`q·r` beyond the dense
    /// limit): at most `rows` configs can be observed regardless of q.
    sparse: HashMap<u32, Vec<u32>>,
}

/// Maximum `q_i · r_i` the dense buffer will grow to; beyond this the
/// sparse (hash-map) path takes over. 3^4 parents × 4 states is 324, so
/// the dense path covers everything the bounded learner does; the
/// exhaustive "all parent sets" mode (up to 19 parents in Table V) goes
/// sparse.
const DENSE_LIMIT: usize = 1 << 22;

impl CountsWorkspace {
    /// Fresh workspace.
    pub fn new() -> Self {
        CountsWorkspace {
            dense: Vec::new(),
            touched: Vec::new(),
            codes: Vec::new(),
            sparse: HashMap::new(),
        }
    }

    /// Count `N_ijk` for `(node, parents)` over `data`.
    ///
    /// Calls `f(n_ik, counts_j)` once per *observed* parent configuration,
    /// where `counts_j` is the dense per-state histogram (`N_ijk` over j)
    /// and `n_ik = Σ_j N_ijk`.
    pub fn for_each_config(
        &mut self,
        data: &Dataset,
        node: usize,
        parents: &[usize],
        mut f: impl FnMut(u32, &[u32]),
    ) {
        let rows = data.rows();
        let arity = data.arity(node);
        // joint parent-config count (checked: codes must fit u32)
        let q_wide: u128 =
            parents.iter().map(|&m| data.arity(m) as u128).product::<u128>().max(1);
        assert!(q_wide <= u32::MAX as u128, "parent config space exceeds u32 codes");
        let q = q_wide as usize;
        let cells = q.saturating_mul(arity);

        // Encode parent configs per row (mixed radix, first parent fastest).
        self.codes.clear();
        self.codes.resize(rows, 0);
        let mut stride = 1u32;
        for &m in parents {
            let col = data.column(m);
            if stride == 1 {
                for (code, &v) in self.codes.iter_mut().zip(col) {
                    *code = v as u32;
                }
            } else {
                for (code, &v) in self.codes.iter_mut().zip(col) {
                    *code += v as u32 * stride;
                }
            }
            stride *= data.arity(m) as u32;
        }

        let node_col = data.column(node);
        if cells <= DENSE_LIMIT {
            // Dense path: grow the buffer lazily; it is kept zeroed
            // between calls via the touched list.
            if self.dense.len() < cells {
                self.dense.resize(cells, 0);
            }
            self.touched.clear();
            for (r, &code) in self.codes.iter().enumerate() {
                let base = code as usize * arity;
                let cell = base + node_col[r] as usize;
                if self.dense[base..base + arity].iter().all(|&c| c == 0) {
                    self.touched.push(code);
                }
                self.dense[cell] += 1;
            }
            // Emit per observed config, then clear. Sorted for
            // deterministic emission (touched ≤ rows).
            self.touched.sort_unstable();
            for &code in &self.touched {
                let base = code as usize * arity;
                let counts = &self.dense[base..base + arity];
                let n_ik: u32 = counts.iter().sum();
                f(n_ik, counts);
            }
            for &code in &self.touched {
                let base = code as usize * arity;
                self.dense[base..base + arity].iter_mut().for_each(|c| *c = 0);
            }
        } else {
            // Sparse path: at most `rows` configs occur no matter how
            // large q is (Table V's exhaustive mode reaches 3^19 configs).
            self.sparse.clear();
            for (r, &code) in self.codes.iter().enumerate() {
                let counts =
                    self.sparse.entry(code).or_insert_with(|| vec![0u32; arity]);
                counts[node_col[r] as usize] += 1;
            }
            self.touched.clear();
            self.touched.extend(self.sparse.keys().copied());
            self.touched.sort_unstable();
            for &code in &self.touched {
                let counts = &self.sparse[&code];
                let n_ik: u32 = counts.iter().sum();
                f(n_ik, counts);
            }
        }
    }
}

impl Default for CountsWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Dataset {
        // X0 ∈ {0,1}, X1 ∈ {0,1,2}, X2 ∈ {0,1}
        Dataset::from_columns(
            vec![
                vec![0, 0, 1, 1, 0, 1],
                vec![0, 1, 2, 0, 1, 2],
                vec![0, 0, 0, 1, 1, 1],
            ],
            vec![2, 3, 2],
        )
    }

    #[test]
    fn no_parents_single_config() {
        let d = dataset();
        let mut ws = CountsWorkspace::new();
        let mut seen = Vec::new();
        ws.for_each_config(&d, 1, &[], |n_ik, counts| {
            seen.push((n_ik, counts.to_vec()));
        });
        // X1 column: [0,1,2,0,1,2] → counts [2,2,2]
        assert_eq!(seen, vec![(6, vec![2, 2, 2])]);
    }

    #[test]
    fn one_parent_counts() {
        let d = dataset();
        let mut ws = CountsWorkspace::new();
        let mut seen = Vec::new();
        ws.for_each_config(&d, 0, &[2], |n_ik, counts| {
            seen.push((n_ik, counts.to_vec()));
        });
        // X2=0 rows {0,1,2}: X0 = [0,0,1] → [2,1]; X2=1 rows {3,4,5}: X0 = [1,0,1] → [1,2]
        assert_eq!(seen, vec![(3, vec![2, 1]), (3, vec![1, 2])]);
    }

    #[test]
    fn two_parents_mixed_radix() {
        let d = dataset();
        let mut ws = CountsWorkspace::new();
        let mut total = 0u32;
        let mut configs = 0usize;
        ws.for_each_config(&d, 0, &[1, 2], |n_ik, counts| {
            assert_eq!(n_ik, counts.iter().sum::<u32>());
            total += n_ik;
            configs += 1;
        });
        assert_eq!(total, 6); // all rows accounted for
        assert!(configs <= 6); // at most q=6 observed configs
    }

    #[test]
    fn workspace_reuse_is_clean() {
        // Two different queries back-to-back must not leak counts.
        let d = dataset();
        let mut ws = CountsWorkspace::new();
        let mut first = Vec::new();
        ws.for_each_config(&d, 0, &[1], |n, c| first.push((n, c.to_vec())));
        let mut again = Vec::new();
        ws.for_each_config(&d, 0, &[1], |n, c| again.push((n, c.to_vec())));
        assert_eq!(first, again);
        // and a differently-shaped query in between
        let mut other = Vec::new();
        ws.for_each_config(&d, 2, &[0, 1], |n, c| other.push((n, c.to_vec())));
        let mut after = Vec::new();
        ws.for_each_config(&d, 0, &[1], |n, c| after.push((n, c.to_vec())));
        assert_eq!(first, after);
    }

    #[test]
    fn totals_always_match_rows() {
        let d = dataset();
        let mut ws = CountsWorkspace::new();
        for node in 0..3 {
            for parents in [vec![], vec![(node + 1) % 3], vec![(node + 1) % 3, (node + 2) % 3]] {
                let mut total = 0u32;
                ws.for_each_config(&d, node, &parents, |n, _| total += n);
                assert_eq!(total as usize, d.rows());
            }
        }
    }
}
