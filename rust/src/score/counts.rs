//! Contingency counting: the `N_ik` / `N_ijk` statistics of Equation (3).
//!
//! For a node `i` with parent set `π`, `N_ijk` is the number of
//! observations where `v_i` is in state `j` and the parents jointly take
//! configuration `k`. Parent configurations are mixed-radix encoded
//! (first parent fastest).
//!
//! Counting is *sparse*: only configurations that actually occur are
//! materialized. Unobserved configurations contribute exactly zero to the
//! BDe score (`logΓ(α)−logΓ(α+0) = 0`), so skipping them is both the
//! correctness-preserving and the fast thing to do — with N observations
//! at most N configurations are touched regardless of `r_i = Π arities`.
//!
//! Configurations are always emitted in ascending code order — the
//! canonical emission order shared with the prefix-cached counter
//! ([`crate::score::prefix::PrefixCounter`]), which is what makes the
//! `--counting naive|prefix` toggle bit-identical.

use std::collections::HashMap;

use crate::data::Dataset;
use crate::score::adcache::CountCacheRef;

/// Reusable scratch for one thread's counting loop; avoids re-allocating
/// and re-zeroing per local score (the preprocessing stage computes
/// millions of them).
#[derive(Debug)]
pub struct CountsWorkspace {
    /// Dense per-(config,state) counts, length = capacity currently held.
    dense: Vec<u32>,
    /// Configs touched this round (for O(touched) clearing).
    touched: Vec<u32>,
    /// Per-row parent config codes (reused across nodes for a fixed π).
    codes: Vec<u32>,
    /// First-touch generation stamps, one per config slot of `dense`
    /// (slot = code, not cell). A config is "new this round" iff its
    /// stamp differs from `epoch` — an O(1) probe replacing the old
    /// O(arity) scan of the dense row.
    stamp: Vec<u32>,
    /// Current counting generation for `stamp`.
    epoch: u32,
    /// Sparse fallback for huge config spaces (`q·r` beyond the dense
    /// limit): at most `rows` configs can be observed regardless of q.
    sparse: HashMap<u32, Vec<u32>>,
    /// Wide-code row encodings for parent spaces beyond u32 (the
    /// exhaustive Table V mode can reach q ≈ 255^19).
    codes_wide: Vec<u128>,
    /// Sparse counts keyed by wide codes.
    sparse_wide: HashMap<u128, Vec<u32>>,
}

/// Maximum `q_i · r_i` the dense buffer will grow to; beyond this the
/// sparse (hash-map) path takes over. 3^4 parents × 4 states is 324, so
/// the dense path covers everything the bounded learner does; the
/// exhaustive "all parent sets" mode (up to 19 parents in Table V) goes
/// sparse.
pub(crate) const DENSE_LIMIT: usize = 1 << 22;

impl CountsWorkspace {
    /// Fresh workspace.
    pub fn new() -> Self {
        CountsWorkspace {
            dense: Vec::new(),
            touched: Vec::new(),
            codes: Vec::new(),
            stamp: Vec::new(),
            epoch: 0,
            sparse: HashMap::new(),
            codes_wide: Vec::new(),
            sparse_wide: HashMap::new(),
        }
    }

    /// Count `N_ijk` for `(node, parents)` over `data`.
    ///
    /// Calls `f(n_ik, counts_j)` once per *observed* parent configuration
    /// in ascending code order, where `counts_j` is the dense per-state
    /// histogram (`N_ijk` over j) and `n_ik = Σ_j N_ijk`.
    pub fn for_each_config(
        &mut self,
        data: &Dataset,
        node: usize,
        parents: &[usize],
        mut f: impl FnMut(u32, &[u32]),
    ) {
        let rows = data.rows();
        let arity = data.arity(node);
        // Joint parent-config count. Codes beyond u32 degrade to the
        // wide (u128) sparse path instead of panicking — exhaustive
        // high-arity parent sets stay scoreable.
        let q_wide: u128 =
            parents.iter().map(|&m| data.arity(m) as u128).product::<u128>().max(1);
        if q_wide > u32::MAX as u128 {
            self.for_each_config_wide(data, node, parents, f);
            return;
        }
        let q = q_wide as usize;
        let cells = q.saturating_mul(arity);

        // Encode parent configs per row (mixed radix, first parent
        // fastest). The first parent *assigns* codes, so no zero-fill is
        // needed when the buffer already has the right length; with no
        // parents we skip the codes pass entirely below.
        if parents.is_empty() {
            // Single config: count the node column directly.
            let node_col = data.column(node);
            if self.dense.len() < arity {
                self.dense.resize(arity, 0);
            }
            let counts = &mut self.dense[..arity];
            counts.iter_mut().for_each(|c| *c = 0);
            for &v in node_col {
                counts[v as usize] += 1;
            }
            let n_ik: u32 = counts.iter().sum();
            f(n_ik, counts);
            self.dense[..arity].iter_mut().for_each(|c| *c = 0);
            return;
        }
        if self.codes.len() != rows {
            self.codes.resize(rows, 0);
        }
        let mut stride = 1u32;
        for (pi, &m) in parents.iter().enumerate() {
            let col = data.column(m);
            if pi == 0 {
                for (code, &v) in self.codes.iter_mut().zip(col) {
                    *code = v as u32;
                }
            } else {
                for (code, &v) in self.codes.iter_mut().zip(col) {
                    *code += v as u32 * stride;
                }
            }
            stride *= data.arity(m) as u32;
        }

        let node_col = data.column(node);
        if cells <= DENSE_LIMIT {
            // Dense path: grow the buffers lazily; `dense` is kept
            // zeroed between calls via the touched list, `stamp` via the
            // epoch counter.
            if self.dense.len() < cells {
                self.dense.resize(cells, 0);
            }
            if self.stamp.len() < q {
                self.stamp.resize(q, 0);
            }
            self.epoch = self.epoch.wrapping_add(1);
            if self.epoch == u32::MAX {
                self.stamp.iter_mut().for_each(|s| *s = 0);
                self.epoch = 1;
            }
            let epoch = self.epoch;
            self.touched.clear();
            for (r, &code) in self.codes.iter().enumerate() {
                let slot = code as usize;
                let cell = slot * arity + node_col[r] as usize;
                if self.stamp[slot] != epoch {
                    self.stamp[slot] = epoch;
                    self.touched.push(code);
                }
                self.dense[cell] += 1;
            }
            // Emit per observed config, then clear. Sorted for
            // deterministic emission (touched ≤ rows).
            self.touched.sort_unstable();
            for &code in &self.touched {
                let base = code as usize * arity;
                let counts = &self.dense[base..base + arity];
                let n_ik: u32 = counts.iter().sum();
                f(n_ik, counts);
            }
            for &code in &self.touched {
                let base = code as usize * arity;
                self.dense[base..base + arity].iter_mut().for_each(|c| *c = 0);
            }
        } else {
            // Sparse path: at most `rows` configs occur no matter how
            // large q is (Table V's exhaustive mode reaches 3^19 configs).
            self.sparse.clear();
            for (r, &code) in self.codes.iter().enumerate() {
                let counts =
                    self.sparse.entry(code).or_insert_with(|| vec![0u32; arity]);
                counts[node_col[r] as usize] += 1;
            }
            self.touched.clear();
            self.touched.extend(self.sparse.keys().copied());
            self.touched.sort_unstable();
            for &code in &self.touched {
                let counts = &self.sparse[&code];
                let n_ik: u32 = counts.iter().sum();
                f(n_ik, counts);
            }
        }
    }

    /// Accumulate the dense `N_ijk` histogram for `(node, parents)`
    /// into `hist[code·r_i + state]` — the count-cache miss path of
    /// naive mode, which needs the full histogram materialized (not
    /// just emitted) so it can be admitted to the cache. Only legal
    /// when `q·r_i` fits the dense regime: `hist.len()` must be
    /// exactly `q · arity(node)`. Adds are plain u32 increments over
    /// rows in order, so the resulting counts are identical to every
    /// other counting path's.
    pub fn accumulate_dense(
        &mut self,
        data: &Dataset,
        node: usize,
        parents: &[usize],
        hist: &mut [u32],
    ) {
        let rows = data.rows();
        let arity = data.arity(node);
        let node_col = data.column(node);
        if parents.is_empty() {
            debug_assert_eq!(hist.len(), arity);
            for &v in node_col {
                hist[v as usize] += 1;
            }
            return;
        }
        if self.codes.len() != rows {
            self.codes.resize(rows, 0);
        }
        let mut stride = 1u32;
        for (pi, &m) in parents.iter().enumerate() {
            let col = data.column(m);
            if pi == 0 {
                for (code, &v) in self.codes.iter_mut().zip(col) {
                    *code = v as u32;
                }
            } else {
                for (code, &v) in self.codes.iter_mut().zip(col) {
                    *code += v as u32 * stride;
                }
            }
            stride *= data.arity(m) as u32;
        }
        for (r, &code) in self.codes.iter().enumerate() {
            hist[code as usize * arity + node_col[r] as usize] += 1;
        }
    }

    /// Wide-code sparse counting for parent spaces whose mixed-radix
    /// codes exceed u32 (q up to 255^19 ≈ 2^152 fits u128 comfortably
    /// for ≤ 19 parents of arity ≤ 255). Emission is ascending-code,
    /// matching the narrow paths.
    fn for_each_config_wide(
        &mut self,
        data: &Dataset,
        node: usize,
        parents: &[usize],
        mut f: impl FnMut(u32, &[u32]),
    ) {
        let rows = data.rows();
        let arity = data.arity(node);
        if self.codes_wide.len() != rows {
            self.codes_wide.resize(rows, 0);
        }
        let mut stride = 1u128;
        for (pi, &m) in parents.iter().enumerate() {
            let col = data.column(m);
            if pi == 0 {
                for (code, &v) in self.codes_wide.iter_mut().zip(col) {
                    *code = v as u128;
                }
            } else {
                for (code, &v) in self.codes_wide.iter_mut().zip(col) {
                    *code += v as u128 * stride;
                }
            }
            stride *= data.arity(m) as u128;
        }
        let node_col = data.column(node);
        self.sparse_wide.clear();
        for (r, &code) in self.codes_wide.iter().enumerate() {
            let counts =
                self.sparse_wide.entry(code).or_insert_with(|| vec![0u32; arity]);
            counts[node_col[r] as usize] += 1;
        }
        let mut keys: Vec<u128> = self.sparse_wide.keys().copied().collect();
        keys.sort_unstable();
        for code in keys {
            let counts = &self.sparse_wide[&code];
            let n_ik: u32 = counts.iter().sum();
            f(n_ik, counts);
        }
    }
}

impl Default for CountsWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

/// Which counting engine drives score-table builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CountingMode {
    /// Reference path: re-encode parent configs from scratch per cell
    /// via [`CountsWorkspace`]. Never chunks.
    Naive,
    /// Prefix-cached path: config codes are refined incrementally along
    /// the subset DFS; eligible for chunked row-scale counting.
    Prefix,
}

impl CountingMode {
    /// Parse a `--counting` flag value.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "naive" => Ok(CountingMode::Naive),
            "prefix" => Ok(CountingMode::Prefix),
            other => anyhow::bail!("unknown counting mode '{other}' (naive|prefix)"),
        }
    }

    /// Canonical flag-value name.
    pub fn name(self) -> &'static str {
        match self {
            CountingMode::Naive => "naive",
            CountingMode::Prefix => "prefix",
        }
    }
}

/// Row-chunk size used when chunking engages automatically
/// (`chunk_rows == 0`).
pub(crate) const AUTO_CHUNK_ROWS: usize = 1 << 15;

/// Minimum dataset size before automatic chunking engages; below this the
/// whole-column walk is already cache-resident and chunk bookkeeping is
/// pure overhead.
pub(crate) const AUTO_MIN_ROWS: usize = 1 << 18;

/// Counting-engine configuration threaded from the CLI down into the
/// table builders.
#[derive(Debug, Clone)]
pub struct CountingConfig {
    /// Engine selection (default [`CountingMode::Prefix`]).
    pub mode: CountingMode,
    /// Row-chunk size for the chunked counting path; `0` = auto
    /// (engage at [`AUTO_MIN_ROWS`] rows with [`AUTO_CHUNK_ROWS`]-row
    /// chunks). Ignored in naive mode.
    pub chunk_rows: usize,
    /// Cross-tile count cache consulted by every counting path
    /// ([`crate::score::adcache`]); `None` = uncached. Pure reuse of
    /// exact u32 counts — never part of config identity (see the
    /// `PartialEq` impl) and never fingerprinted.
    pub cache: Option<CountCacheRef>,
}

/// Equality compares the *result-shaping* knobs only: the cache is a
/// work-saving attachment that cannot change a single output bit, so
/// two configs differing only in `cache` are the same configuration
/// (the CLI round-trip tests compare against the bare constructors).
impl PartialEq for CountingConfig {
    fn eq(&self, other: &Self) -> bool {
        self.mode == other.mode && self.chunk_rows == other.chunk_rows
    }
}

impl Eq for CountingConfig {}

impl CountingConfig {
    /// The reference configuration: naive counting, never chunked.
    pub fn naive() -> Self {
        CountingConfig { mode: CountingMode::Naive, chunk_rows: 0, cache: None }
    }

    /// The default configuration: prefix counting, auto chunking.
    pub fn prefix() -> Self {
        CountingConfig { mode: CountingMode::Prefix, chunk_rows: 0, cache: None }
    }

    /// This configuration with a count cache attached.
    pub fn with_cache(mut self, cache: CountCacheRef) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Chunk size to use for a dataset of `rows` rows, or `None` to count
    /// whole columns. Naive mode never chunks (it is the reference path).
    pub fn chunk_for(&self, rows: usize) -> Option<usize> {
        if self.mode != CountingMode::Prefix {
            return None;
        }
        if self.chunk_rows == 0 {
            if rows >= AUTO_MIN_ROWS {
                Some(AUTO_CHUNK_ROWS)
            } else {
                None
            }
        } else if rows > self.chunk_rows {
            Some(self.chunk_rows)
        } else {
            None
        }
    }
}

impl Default for CountingConfig {
    fn default() -> Self {
        Self::prefix()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Dataset {
        // X0 ∈ {0,1}, X1 ∈ {0,1,2}, X2 ∈ {0,1}
        Dataset::from_columns(
            vec![
                vec![0, 0, 1, 1, 0, 1],
                vec![0, 1, 2, 0, 1, 2],
                vec![0, 0, 0, 1, 1, 1],
            ],
            vec![2, 3, 2],
        )
    }

    #[test]
    fn no_parents_single_config() {
        let d = dataset();
        let mut ws = CountsWorkspace::new();
        let mut seen = Vec::new();
        ws.for_each_config(&d, 1, &[], |n_ik, counts| {
            seen.push((n_ik, counts.to_vec()));
        });
        // X1 column: [0,1,2,0,1,2] → counts [2,2,2]
        assert_eq!(seen, vec![(6, vec![2, 2, 2])]);
    }

    #[test]
    fn one_parent_counts() {
        let d = dataset();
        let mut ws = CountsWorkspace::new();
        let mut seen = Vec::new();
        ws.for_each_config(&d, 0, &[2], |n_ik, counts| {
            seen.push((n_ik, counts.to_vec()));
        });
        // X2=0 rows {0,1,2}: X0 = [0,0,1] → [2,1]; X2=1 rows {3,4,5}: X0 = [1,0,1] → [1,2]
        assert_eq!(seen, vec![(3, vec![2, 1]), (3, vec![1, 2])]);
    }

    #[test]
    fn two_parents_mixed_radix() {
        let d = dataset();
        let mut ws = CountsWorkspace::new();
        let mut total = 0u32;
        let mut configs = 0usize;
        ws.for_each_config(&d, 0, &[1, 2], |n_ik, counts| {
            assert_eq!(n_ik, counts.iter().sum::<u32>());
            total += n_ik;
            configs += 1;
        });
        assert_eq!(total, 6); // all rows accounted for
        assert!(configs <= 6); // at most q=6 observed configs
    }

    #[test]
    fn workspace_reuse_is_clean() {
        // Two different queries back-to-back must not leak counts.
        let d = dataset();
        let mut ws = CountsWorkspace::new();
        let mut first = Vec::new();
        ws.for_each_config(&d, 0, &[1], |n, c| first.push((n, c.to_vec())));
        let mut again = Vec::new();
        ws.for_each_config(&d, 0, &[1], |n, c| again.push((n, c.to_vec())));
        assert_eq!(first, again);
        // and a differently-shaped query in between
        let mut other = Vec::new();
        ws.for_each_config(&d, 2, &[0, 1], |n, c| other.push((n, c.to_vec())));
        let mut after = Vec::new();
        ws.for_each_config(&d, 0, &[1], |n, c| after.push((n, c.to_vec())));
        assert_eq!(first, after);
    }

    #[test]
    fn totals_always_match_rows() {
        let d = dataset();
        let mut ws = CountsWorkspace::new();
        for node in 0..3 {
            for parents in [vec![], vec![(node + 1) % 3], vec![(node + 1) % 3, (node + 2) % 3]] {
                let mut total = 0u32;
                ws.for_each_config(&d, node, &parents, |n, _| total += n);
                assert_eq!(total as usize, d.rows());
            }
        }
    }

    #[test]
    fn reuse_across_different_row_counts() {
        // The codes buffer must resize correctly when the workspace is
        // reused against a dataset with a different row count.
        let small = dataset();
        let big = Dataset::from_columns(
            vec![
                vec![0, 1, 0, 1, 0, 1, 0, 1, 1, 0],
                vec![0, 0, 1, 1, 2, 2, 0, 1, 2, 0],
            ],
            vec![2, 3],
        );
        let mut ws = CountsWorkspace::new();
        let mut a = Vec::new();
        ws.for_each_config(&big, 0, &[1], |n, c| a.push((n, c.to_vec())));
        let mut b = Vec::new();
        ws.for_each_config(&small, 0, &[1], |n, c| b.push((n, c.to_vec())));
        let mut a2 = Vec::new();
        ws.for_each_config(&big, 0, &[1], |n, c| a2.push((n, c.to_vec())));
        assert_eq!(a, a2);
        let total: u32 = b.iter().map(|(n, _)| n).sum();
        assert_eq!(total as usize, small.rows());
    }

    #[test]
    fn wide_codes_fall_back_gracefully() {
        // 5 parents of arity 200 → q = 3.2e11 > u32::MAX: must not panic,
        // and totals must still cover every row.
        let rows = 64usize;
        let mut cols: Vec<Vec<u8>> = Vec::new();
        let mut state = 0x9e3779b9u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u8
        };
        for _ in 0..6 {
            cols.push((0..rows).map(|_| next() % 200).collect());
        }
        let d = Dataset::from_columns(cols, vec![200; 6]);
        let mut ws = CountsWorkspace::new();
        let mut total = 0u32;
        let mut configs = 0usize;
        ws.for_each_config(&d, 0, &[1, 2, 3, 4, 5], |n, c| {
            assert_eq!(n, c.iter().sum::<u32>());
            total += n;
            configs += 1;
        });
        assert_eq!(total as usize, rows);
        assert!(configs <= rows);
    }

    #[test]
    fn counting_mode_parse_roundtrip() {
        assert_eq!(CountingMode::parse("naive").unwrap(), CountingMode::Naive);
        assert_eq!(CountingMode::parse("prefix").unwrap(), CountingMode::Prefix);
        assert!(CountingMode::parse("magic").is_err());
        assert_eq!(CountingMode::Naive.name(), "naive");
        assert_eq!(CountingMode::Prefix.name(), "prefix");
    }

    #[test]
    fn chunk_for_policy() {
        let naive = CountingConfig::naive();
        assert_eq!(naive.chunk_for(10_000_000), None);
        let auto = CountingConfig::prefix();
        assert_eq!(auto.chunk_for(1000), None);
        assert_eq!(auto.chunk_for(AUTO_MIN_ROWS), Some(AUTO_CHUNK_ROWS));
        let explicit = CountingConfig { chunk_rows: 500, ..CountingConfig::prefix() };
        assert_eq!(explicit.chunk_for(400), None);
        assert_eq!(explicit.chunk_for(501), Some(500));
    }

    #[test]
    fn accumulate_dense_matches_emission() {
        let d = dataset();
        let mut ws = CountsWorkspace::new();
        for (node, parents) in
            [(0usize, vec![]), (0, vec![2]), (0, vec![1, 2]), (1, vec![0]), (2, vec![0, 1])]
        {
            let r_i = d.arity(node);
            let q: usize = parents.iter().map(|&p| d.arity(p)).product::<usize>().max(1);
            let mut hist = vec![0u32; q * r_i];
            ws.accumulate_dense(&d, node, &parents, &mut hist);
            // The dense histogram scanned in ascending code order must
            // reproduce for_each_config's emission exactly.
            let mut from_hist = Vec::new();
            for code in 0..q {
                let counts = &hist[code * r_i..(code + 1) * r_i];
                let n_ik: u32 = counts.iter().sum();
                if n_ik > 0 {
                    from_hist.push((n_ik, counts.to_vec()));
                }
            }
            let mut emitted = Vec::new();
            ws.for_each_config(&d, node, &parents, |n, c| emitted.push((n, c.to_vec())));
            assert_eq!(from_hist, emitted, "node {node} parents {parents:?}");
        }
    }

    #[test]
    fn config_equality_ignores_the_cache() {
        use crate::score::adcache::{CountCache, CountCacheRef};
        use std::sync::Arc;
        let cached = CountingConfig::prefix().with_cache(CountCacheRef {
            cache: Arc::new(CountCache::new(1 << 20, 0)),
            dataset_key: 42,
        });
        assert_eq!(cached, CountingConfig::prefix());
        assert_ne!(cached, CountingConfig::naive());
    }
}
