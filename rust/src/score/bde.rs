//! The paper's local score — Equation (4), log₁₀-space Bayesian-Dirichlet
//! with a γ^|π| structure-complexity penalty.
//!
//! ```text
//! ls(i,π) = |π|·log₁₀γ + Σ_k [ log₁₀Γ(α_ik) − log₁₀Γ(α_ik + N_ik)
//!                            + Σ_j ( log₁₀Γ(N_ijk + α_ijk) − log₁₀Γ(α_ijk) ) ]
//! ```
//!
//! Two standard hyperparameter schemes are supported:
//! * **K2** (Cooper–Herskovits): `α_ijk = 1` — the paper's reference [13].
//! * **BDeu**: `α_ijk = α_ess / (q_i · r_i)` — likelihood-equivalent.
//!
//! Only observed parent configurations contribute (see `counts`); for the
//! BDeu scheme the per-config prior still depends on the *total* number of
//! configurations `q_i`, which we compute from arities, not from counts.

use super::counts::CountsWorkspace;
use super::lgamma::{log10_gamma, log10_rising};
use crate::data::Dataset;

/// Hyperparameter scheme for the Dirichlet prior.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DirichletPrior {
    /// `α_ijk = 1` for every cell.
    K2,
    /// `α_ijk = ess / (q_i · r_i)`.
    BDeu { ess: f64 },
}

/// Scoring parameters.
#[derive(Debug, Clone, Copy)]
pub struct BdeParams {
    /// Structure penalty γ ∈ (0, 1]; each parent costs `log₁₀ γ`.
    pub gamma: f64,
    /// Dirichlet scheme.
    pub prior: DirichletPrior,
}

impl Default for BdeParams {
    fn default() -> Self {
        // γ = 0.1 ⇒ one decade of posterior odds per extra parent — strong
        // enough to prune spurious parents at N=1000, matching the paper's
        // "penalty for complex structures".
        BdeParams { gamma: 0.1, prior: DirichletPrior::K2 }
    }
}

/// Computes local scores `ls(i, π)` over one dataset.
///
/// Owns a counting workspace, so one `LocalScorer` per thread.
pub struct LocalScorer<'a> {
    data: &'a Dataset,
    params: BdeParams,
    ws: CountsWorkspace,
    log10_gamma_pen: f64,
}

impl<'a> LocalScorer<'a> {
    /// New scorer over `data`.
    pub fn new(data: &'a Dataset, params: BdeParams) -> Self {
        assert!(params.gamma > 0.0 && params.gamma <= 1.0, "gamma must be in (0,1]");
        LocalScorer { data, params, ws: CountsWorkspace::new(), log10_gamma_pen: params.gamma.log10() }
    }

    /// The dataset being scored.
    pub fn data(&self) -> &'a Dataset {
        self.data
    }

    /// Scoring parameters.
    pub fn params(&self) -> BdeParams {
        self.params
    }

    /// The paper's Equation (4): log₁₀ local score of `node` with sorted
    /// parent set `parents`.
    pub fn score(&mut self, node: usize, parents: &[usize]) -> f64 {
        debug_assert!(!parents.contains(&node), "node cannot parent itself");
        let r_i = self.data.arity(node);
        let q_i: usize =
            parents.iter().map(|&m| self.data.arity(m)).product::<usize>().max(1);

        let (alpha_ijk, alpha_ik) = match self.params.prior {
            DirichletPrior::K2 => (1.0, r_i as f64),
            DirichletPrior::BDeu { ess } => {
                let a = ess / (q_i as f64 * r_i as f64);
                (a, ess / q_i as f64)
            }
        };

        let mut score = parents.len() as f64 * self.log10_gamma_pen;
        let lg_alpha_ik = log10_gamma(alpha_ik);
        let lg_alpha_ijk = log10_gamma(alpha_ijk);
        let mut acc = 0f64;
        self.ws.for_each_config(self.data, node, parents, |n_ik, counts| {
            // log10 Γ(α_ik) − log10 Γ(α_ik + N_ik)
            acc += lg_alpha_ik - log10_gamma(alpha_ik + n_ik as f64);
            // + Σ_j log10 Γ(N_ijk + α_ijk) − log10 Γ(α_ijk)
            for &n_ijk in counts {
                if n_ijk > 0 {
                    acc += log10_gamma(n_ijk as f64 + alpha_ijk) - lg_alpha_ijk;
                }
            }
            let _ = log10_rising; // (kept for the optimization pass)
        });
        score += acc;
        score
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{Dag, Network};
    use crate::bn::sampling::forward_sample;
    use crate::util::Pcg32;

    fn tiny_data() -> Dataset {
        Dataset::from_columns(
            vec![vec![0, 0, 1, 1, 0, 1, 0, 1], vec![0, 0, 1, 1, 0, 1, 1, 0]],
            vec![2, 2],
        )
    }

    /// Brute-force Eq. (4) for one node/parent pair with K2 prior, written
    /// independently of the production code path (dense loop over all
    /// configs, naive lgamma) — the oracle.
    fn k2_oracle(data: &Dataset, node: usize, parents: &[usize], gamma: f64) -> f64 {
        let r = data.arity(node);
        let q: usize = parents.iter().map(|&m| data.arity(m)).product::<usize>().max(1);
        let mut n_jk = vec![0u32; q * r];
        for row in 0..data.rows() {
            let mut cfg = 0usize;
            let mut stride = 1usize;
            for &m in parents {
                cfg += data.value(row, m) as usize * stride;
                stride *= data.arity(m);
            }
            n_jk[cfg * r + data.value(row, node) as usize] += 1;
        }
        let mut score = parents.len() as f64 * gamma.log10();
        for k in 0..q {
            let counts = &n_jk[k * r..(k + 1) * r];
            let n_k: u32 = counts.iter().sum();
            score += log10_gamma(r as f64) - log10_gamma(r as f64 + n_k as f64);
            for &c in counts {
                score += log10_gamma(c as f64 + 1.0) - log10_gamma(1.0);
            }
        }
        score
    }

    #[test]
    fn matches_oracle_tiny() {
        let d = tiny_data();
        let mut s = LocalScorer::new(&d, BdeParams::default());
        for (node, parents) in [(0usize, vec![]), (0, vec![1]), (1, vec![0])] {
            let got = s.score(node, &parents);
            let want = k2_oracle(&d, node, &parents, 0.1);
            assert!((got - want).abs() < 1e-9, "{node} {parents:?}: {got} vs {want}");
        }
    }

    #[test]
    fn matches_oracle_random_sweep() {
        // Property sweep: random small networks, all (node, parents≤2) pairs.
        let mut rng = Pcg32::new(31);
        for trial in 0..10 {
            let dag = crate::bn::random::random_dag(5, 2, 5, &mut rng);
            let net = Network::with_random_cpts(dag, vec![2, 3, 2, 3, 2], &mut rng);
            let data = forward_sample(&net, 200, &mut rng);
            let mut s = LocalScorer::new(&data, BdeParams::default());
            for node in 0..5usize {
                for p1 in 0..5usize {
                    if p1 == node {
                        continue;
                    }
                    for p2 in (p1 + 1)..5 {
                        if p2 == node {
                            continue;
                        }
                        let parents = vec![p1, p2];
                        let got = s.score(node, &parents);
                        let want = k2_oracle(&data, node, &parents, 0.1);
                        assert!(
                            (got - want).abs() < 1e-8,
                            "trial {trial} node {node} {parents:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn true_parent_beats_noise_parent() {
        // X0 → X1 strongly; X2 independent. ls(1, {0}) ≫ ls(1, {2}).
        let mut rng = Pcg32::new(33);
        let dag = Dag::from_edges(3, &[(0, 1)]);
        let mut net = Network::with_random_cpts(dag, vec![2, 2, 2], &mut rng);
        net.cpts[1].probs = vec![0.95, 0.05, 0.05, 0.95];
        let data = forward_sample(&net, 1000, &mut rng);
        let mut s = LocalScorer::new(&data, BdeParams::default());
        let with_true = s.score(1, &[0]);
        let with_noise = s.score(1, &[2]);
        let alone = s.score(1, &[]);
        assert!(with_true > alone, "{with_true} vs {alone}");
        assert!(alone > with_noise, "{alone} vs {with_noise}"); // γ penalty + no signal
    }

    #[test]
    fn gamma_penalty_monotone() {
        // Pure-noise data: more parents ⇒ lower score (penalty dominates).
        let mut rng = Pcg32::new(34);
        let dag = Dag::empty(4);
        let net = Network::with_random_cpts(dag, vec![2; 4], &mut rng);
        let data = forward_sample(&net, 500, &mut rng);
        let mut s = LocalScorer::new(&data, BdeParams::default());
        let s0 = s.score(0, &[]);
        let s1 = s.score(0, &[1]);
        let s2 = s.score(0, &[1, 2]);
        assert!(s0 > s1 && s1 > s2, "{s0} {s1} {s2}");
    }

    #[test]
    fn bdeu_prior_runs_and_differs() {
        let d = tiny_data();
        let mut k2 = LocalScorer::new(&d, BdeParams::default());
        let mut bdeu = LocalScorer::new(
            &d,
            BdeParams { gamma: 0.1, prior: DirichletPrior::BDeu { ess: 1.0 } },
        );
        let a = k2.score(0, &[1]);
        let b = bdeu.score(0, &[1]);
        assert!(a.is_finite() && b.is_finite());
        assert!((a - b).abs() > 1e-9, "K2 and BDeu should differ on this data");
    }

    #[test]
    fn score_is_a_log_probability_scale() {
        // More data ⇒ more negative scores, roughly linearly.
        let mut rng = Pcg32::new(35);
        let dag = Dag::empty(2);
        let net = Network::with_random_cpts(dag, vec![2, 2], &mut rng);
        let d1 = forward_sample(&net, 100, &mut rng);
        let d2 = forward_sample(&net, 1000, &mut rng);
        let mut s1 = LocalScorer::new(&d1, BdeParams::default());
        let mut s2 = LocalScorer::new(&d2, BdeParams::default());
        assert!(s2.score(0, &[]) < s1.score(0, &[]));
    }
}
