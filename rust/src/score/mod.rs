//! Bayesian–Dirichlet scoring: the paper's Equations (3)/(4) plus the
//! preprocessing stage that materializes every local score once
//! (Section III-A).

pub mod bde;
pub mod counts;
pub mod lgamma;
pub mod table;

pub use bde::{BdeParams, LocalScorer};
pub use lgamma::{lgamma, log10_gamma};
pub use table::ScoreTable;
