//! Bayesian–Dirichlet scoring: the paper's Equations (3)/(4) plus the
//! preprocessing stage that materializes every local score once
//! (Section III-A).

pub mod adcache;
pub mod bde;
pub mod counts;
pub mod lgamma;
pub mod prefix;
pub mod store;
pub mod table;

pub use adcache::{CountCache, CountCacheRef};
pub use bde::{BdeParams, LocalScorer};
pub use counts::{CountingConfig, CountingMode, CountsWorkspace};
pub use lgamma::{lgamma, log10_gamma};
pub use prefix::PrefixCounter;
pub use store::{HashScoreStore, ScoreStore};
pub use table::{ScoreTable, NEG_SENTINEL};
