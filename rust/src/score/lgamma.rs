//! Log-gamma, implemented from scratch (no `libm`/`statrs` offline).
//!
//! Lanczos approximation (g = 7, n = 9 coefficients — Numerical Recipes'
//! set), accurate to ~1e-13 relative over the positive reals, which is far
//! below the 1e-6 tolerances that matter for comparing BDe scores.
//! The paper computes scores as log10; we provide both bases.

/// Lanczos g=7, 9-term coefficients.
const LANCZOS_G: f64 = 7.0;
const LANCZOS_COEF: [f64; 9] = [
    0.99999999999980993,
    676.5203681218851,
    -1259.1392167224028,
    771.32342877765313,
    -176.61502916214059,
    12.507343278686905,
    -0.13857109526572012,
    9.9843695780195716e-6,
    1.5056327351493116e-7,
];

const LN_SQRT_2PI: f64 = 0.91893853320467274178; // ln(sqrt(2π))
const LOG10_E: f64 = std::f64::consts::LOG10_E;

/// Natural-log gamma for `x > 0`.
pub fn lgamma(x: f64) -> f64 {
    assert!(x > 0.0, "lgamma domain: x must be positive, got {x}");
    // Reflection is unnecessary for x > 0; Lanczos works directly with the
    // shifted series on x (series written for Γ(z) with z = x).
    let z = x - 1.0;
    let mut acc = LANCZOS_COEF[0];
    for (i, &c) in LANCZOS_COEF.iter().enumerate().skip(1) {
        acc += c / (z + i as f64);
    }
    let t = z + LANCZOS_G + 0.5;
    LN_SQRT_2PI + (z + 0.5) * t.ln() - t + acc.ln()
}

/// Base-10 log gamma — the paper's `log10 Γ(·)` (Equation 4).
#[inline]
pub fn log10_gamma(x: f64) -> f64 {
    lgamma(x) * LOG10_E
}

/// `log10 Γ(x+n) - log10 Γ(x)` — the rising-factorial differences that
/// Eq. (4) is built from, exposed for the fast-path that avoids two large
/// cancelling lgamma calls when `n` is a small integer.
pub fn log10_rising(x: f64, n: u32) -> f64 {
    // For small n the product form is cheaper and exact-er.
    if n <= 24 {
        let mut acc = 0f64;
        for k in 0..n {
            acc += (x + k as f64).log10();
        }
        acc
    } else {
        log10_gamma(x + n as f64) - log10_gamma(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integer_values_are_factorials() {
        // Γ(n) = (n-1)!
        let facts = [1.0f64, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0, 5040.0];
        for (n, &f) in facts.iter().enumerate() {
            let expect = f.ln();
            assert!((lgamma((n + 1) as f64) - expect).abs() < 1e-10, "n={n}");
        }
    }

    #[test]
    fn half_integer_value() {
        // Γ(1/2) = sqrt(π)
        let expect = std::f64::consts::PI.sqrt().ln();
        assert!((lgamma(0.5) - expect).abs() < 1e-12);
        // Γ(3/2) = sqrt(π)/2
        let expect = (std::f64::consts::PI.sqrt() / 2.0).ln();
        assert!((lgamma(1.5) - expect).abs() < 1e-12);
    }

    #[test]
    fn recurrence_property() {
        // lgamma(x+1) = lgamma(x) + ln(x), swept over magnitudes.
        for &x in &[1e-3, 0.1, 0.5, 1.0, 2.5, 10.0, 100.0, 1e4, 1e6] {
            let lhs = lgamma(x + 1.0);
            let rhs = lgamma(x) + x.ln();
            assert!((lhs - rhs).abs() < 1e-9 * lhs.abs().max(1.0), "x={x}");
        }
    }

    #[test]
    fn large_argument_stirling() {
        // Stirling check at x = 1e6.
        let x = 1e6f64;
        let stirling = (x - 0.5) * x.ln() - x + LN_SQRT_2PI;
        assert!((lgamma(x) - stirling).abs() / stirling < 1e-7);
    }

    #[test]
    fn log10_base_conversion() {
        assert!((log10_gamma(10.0) - lgamma(10.0) / std::f64::consts::LN_10).abs() < 1e-12);
    }

    #[test]
    fn rising_factorial_agreement() {
        for &x in &[0.25f64, 1.0, 3.5, 100.0] {
            for &n in &[0u32, 1, 5, 24, 25, 100, 1000] {
                let direct = log10_gamma(x + n as f64) - log10_gamma(x);
                let fast = log10_rising(x, n);
                assert!(
                    (direct - fast).abs() < 1e-8 * direct.abs().max(1.0),
                    "x={x} n={n}: {direct} vs {fast}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "domain")]
    fn rejects_nonpositive() {
        lgamma(0.0);
    }
}
