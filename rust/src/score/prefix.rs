//! Prefix-cached parent-config counting.
//!
//! The subset DFS in `FastRowBuilder` visits parent sets in nested order:
//! descending from π to π∪{m} adds exactly one parent. [`PrefixCounter`]
//! exploits that by keeping a *stack* of per-row config-code vectors, one
//! per DFS depth, so the codes for π∪{m} are refined from the codes for π
//! with a single column scan and one radix multiply instead of re-encoding
//! the whole mixed-radix product from scratch (the naive
//! [`crate::score::counts::CountsWorkspace`] path).
//!
//! Invariants (the "prefix-stack contract", DESIGN.md §14):
//!
//! - `codes[0]` is always all-zero over the current window (the empty
//!   parent set has the single config 0).
//! - After a successful `push_level(d, col, arity)`, `codes[d + 1][r] =
//!   codes[d][r] + col[lo + r] · strides[d]` and `strides[d + 1] =
//!   strides[d] · arity` — i.e. level `d + 1` holds the mixed-radix codes
//!   (first parent fastest) of the DFS path's first `d + 1` parents.
//! - Codes at depths below a failed push are *stale*; `overflow_from`
//!   records the shallowest invalid depth and `q_at` refuses to vouch for
//!   it. Re-pushing at or above that depth (as the DFS backtracks)
//!   revalidates the stack.
//! - Emission order from `count_window` is ascending config code — the
//!   same canonical order as `CountsWorkspace`, which is what makes
//!   `--counting naive` and `--counting prefix` bit-identical.

/// Stack of per-row parent-config codes aligned with the subset DFS.
#[derive(Debug)]
pub struct PrefixCounter {
    /// `codes[d]` = per-row codes for the first `d` parents of the
    /// current DFS path, over rows `lo..hi`.
    codes: Vec<Vec<u32>>,
    /// `strides[d]` = Π of the first `d` parent arities (= q at depth d).
    strides: Vec<u32>,
    /// Current row window (codes vectors have length `hi - lo`).
    lo: usize,
    hi: usize,
    /// Shallowest depth whose codes could not be computed (u32 overflow).
    overflow_from: Option<usize>,
    /// Dense per-(config,state) counts for leaf emission.
    dense: Vec<u32>,
    /// Configs touched by the current leaf (for sorted emission and
    /// O(touched) clearing).
    touched: Vec<u32>,
    /// First-touch generation stamps, one per config slot.
    stamp: Vec<u32>,
    /// Current generation for `stamp`.
    epoch: u32,
}

impl PrefixCounter {
    /// Counter able to hold DFS paths up to `s` parents deep. Starts with
    /// an empty row window — call [`set_window`](Self::set_window) before
    /// pushing levels.
    pub fn new(s: usize) -> Self {
        PrefixCounter {
            codes: vec![Vec::new(); s + 1],
            strides: vec![1; s + 1],
            lo: 0,
            hi: 0,
            overflow_from: None,
            dense: Vec::new(),
            touched: Vec::new(),
            stamp: Vec::new(),
            epoch: 0,
        }
    }

    /// Point the counter at rows `lo..hi`. No-op when the window is
    /// unchanged; otherwise invalidates all pushed levels (level 0 is
    /// re-zeroed, deeper levels are resized but left stale — they are
    /// fully overwritten by subsequent pushes).
    pub fn set_window(&mut self, lo: usize, hi: usize) {
        debug_assert!(lo <= hi);
        if self.lo == lo && self.hi == hi && !self.codes[0].is_empty() == (hi > lo) {
            return;
        }
        self.lo = lo;
        self.hi = hi;
        let wlen = hi - lo;
        self.codes[0].clear();
        self.codes[0].resize(wlen, 0);
        for level in self.codes.iter_mut().skip(1) {
            level.resize(wlen, 0);
        }
        self.overflow_from = None;
    }

    /// Current row window as `(lo, hi)`.
    pub fn window(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    /// Refine codes from depth `level` to depth `level + 1` by adding one
    /// parent with the given full data column and arity. Returns `false`
    /// (leaving depth `level + 1` flagged invalid) if the refined codes
    /// would overflow u32 or if depth `level` is itself invalid; callers
    /// then fall back to naive counting at affected leaves.
    pub fn push_level(&mut self, level: usize, col: &[u8], arity: usize) -> bool {
        let depth = level + 1;
        debug_assert!(depth < self.codes.len());
        if let Some(f) = self.overflow_from {
            if f <= level {
                // Source codes are stale; deeper levels stay invalid.
                return false;
            }
        }
        let stride = self.strides[level];
        let wide = stride as u64 * arity as u64;
        if wide > u32::MAX as u64 {
            self.overflow_from = Some(depth);
            return false;
        }
        let window = &col[self.lo..self.hi];
        // Split-borrow the source and destination levels.
        let (lower, upper) = self.codes.split_at_mut(depth);
        let src = &lower[level];
        let dst = &mut upper[0];
        if stride == 1 {
            // Depth 1 from the all-zero base: assign directly.
            for (d, &v) in dst.iter_mut().zip(window) {
                *d = v as u32;
            }
        } else {
            for ((d, &s), &v) in dst.iter_mut().zip(src).zip(window) {
                *d = s + v as u32 * stride;
            }
        }
        self.strides[depth] = wide as u32;
        if let Some(f) = self.overflow_from {
            if f >= depth {
                self.overflow_from = None;
            }
        }
        true
    }

    /// Joint parent-config count `q` at depth `k`, or `None` if that
    /// depth's codes are invalid (u32 overflow somewhere at or above it).
    pub fn q_at(&self, k: usize) -> Option<usize> {
        if k == 0 {
            return Some(1);
        }
        if let Some(f) = self.overflow_from {
            if f <= k {
                return None;
            }
        }
        Some(self.strides[k] as usize)
    }

    /// Count `N_ijk` over the current window using depth-`k` codes and
    /// emit `(n_ik, counts_j)` per observed config in ascending code
    /// order — the same contract as `CountsWorkspace::for_each_config`.
    ///
    /// Caller must ensure `q_at(k)` is `Some(q)` with `q · r_i` within
    /// the dense limit; larger leaves take the naive fallback.
    pub fn count_window(
        &mut self,
        k: usize,
        node_col: &[u8],
        r_i: usize,
        mut emit: impl FnMut(u32, &[u32]),
    ) {
        let q = self.strides[k] as usize;
        let cells = q * r_i;
        if self.dense.len() < cells {
            self.dense.resize(cells, 0);
        }
        if self.stamp.len() < q {
            self.stamp.resize(q, 0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == u32::MAX {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 1;
        }
        let epoch = self.epoch;
        self.touched.clear();
        let window = &node_col[self.lo..self.hi];
        for (&code, &v) in self.codes[k].iter().zip(window) {
            let slot = code as usize;
            if self.stamp[slot] != epoch {
                self.stamp[slot] = epoch;
                self.touched.push(code);
            }
            self.dense[slot * r_i + v as usize] += 1;
        }
        self.touched.sort_unstable();
        for &code in &self.touched {
            let base = code as usize * r_i;
            let counts = &self.dense[base..base + r_i];
            let n_ik: u32 = counts.iter().sum();
            emit(n_ik, counts);
        }
        for &code in &self.touched {
            let base = code as usize * r_i;
            self.dense[base..base + r_i].iter_mut().for_each(|c| *c = 0);
        }
    }

    /// Accumulate window counts into an external histogram laid out as
    /// `hist[code · r_i + state]` (length `q · r_i`). Used by the chunked
    /// path: u32 adds commute, so merging per-chunk partials in any order
    /// yields bit-identical totals.
    pub fn accumulate_window(&self, k: usize, node_col: &[u8], r_i: usize, hist: &mut [u32]) {
        let window = &node_col[self.lo..self.hi];
        for (&code, &v) in self.codes[k].iter().zip(window) {
            hist[code as usize * r_i + v as usize] += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_match_mixed_radix() {
        // Two parents: arities 3 then 2, first parent fastest.
        let p0: Vec<u8> = vec![0, 1, 2, 0, 1, 2];
        let p1: Vec<u8> = vec![0, 0, 0, 1, 1, 1];
        let mut pc = PrefixCounter::new(2);
        pc.set_window(0, 6);
        assert!(pc.push_level(0, &p0, 3));
        assert!(pc.push_level(1, &p1, 2));
        assert_eq!(pc.q_at(2), Some(6));
        // code = p0 + 3*p1
        let expected: Vec<u32> = p0
            .iter()
            .zip(&p1)
            .map(|(&a, &b)| a as u32 + 3 * b as u32)
            .collect();
        assert_eq!(pc.codes[2], expected);
    }

    #[test]
    fn windowed_codes_are_offset() {
        let p0: Vec<u8> = vec![9, 9, 0, 1, 2, 9];
        let mut pc = PrefixCounter::new(1);
        pc.set_window(2, 5);
        assert!(pc.push_level(0, &p0, 10));
        assert_eq!(pc.codes[1], vec![0, 1, 2]);
        // Re-setting the same window is a no-op; a new window re-zeroes
        // the base level.
        pc.set_window(2, 5);
        assert_eq!(pc.codes[1], vec![0, 1, 2]);
        pc.set_window(0, 2);
        assert_eq!(pc.codes[0], vec![0, 0]);
    }

    #[test]
    fn overflow_flags_and_recovers() {
        let col: Vec<u8> = vec![0; 4];
        let big: Vec<u8> = vec![1; 4];
        let mut pc = PrefixCounter::new(3);
        pc.set_window(0, 4);
        assert!(pc.push_level(0, &col, 1 << 20));
        // 2^20 · 2^20 overflows u32 → depth 2 invalid.
        assert!(!pc.push_level(1, &big, 1 << 20));
        assert_eq!(pc.q_at(1), Some(1 << 20));
        assert_eq!(pc.q_at(2), None);
        assert_eq!(pc.q_at(3), None);
        // Deeper pushes while invalid also fail.
        assert!(!pc.push_level(2, &col, 2));
        // Backtrack: re-push depth 2 with a small arity → recovered.
        assert!(pc.push_level(1, &big, 2));
        assert_eq!(pc.q_at(2), Some(1 << 21));
        assert!(pc.push_level(2, &col, 2));
        assert_eq!(pc.q_at(3), Some(1 << 22));
    }

    #[test]
    fn count_window_sorted_emission() {
        let p0: Vec<u8> = vec![2, 0, 2, 1, 0, 2];
        let node: Vec<u8> = vec![0, 1, 1, 0, 0, 1];
        let mut pc = PrefixCounter::new(1);
        pc.set_window(0, 6);
        assert!(pc.push_level(0, &p0, 3));
        let mut seen = Vec::new();
        pc.count_window(1, &node, 2, |n, c| seen.push((n, c.to_vec())));
        // code 0: rows 1,4 → node [1,0] → [1,1]; code 1: row 3 → [1,0];
        // code 2: rows 0,2,5 → [1,2]
        assert_eq!(
            seen,
            vec![(2, vec![1, 1]), (1, vec![1, 0]), (3, vec![1, 2])]
        );
        // Reuse is clean.
        let mut again = Vec::new();
        pc.count_window(1, &node, 2, |n, c| again.push((n, c.to_vec())));
        assert_eq!(seen, again);
    }

    #[test]
    fn accumulate_matches_count() {
        let p0: Vec<u8> = vec![2, 0, 2, 1, 0, 2, 1, 1];
        let node: Vec<u8> = vec![0, 1, 1, 0, 0, 1, 1, 0];
        let mut pc = PrefixCounter::new(1);
        // Whole-window count.
        pc.set_window(0, 8);
        assert!(pc.push_level(0, &p0, 3));
        let mut whole = vec![0u32; 3 * 2];
        pc.accumulate_window(1, &node, 2, &mut whole);
        // Two chunks merged.
        let mut merged = vec![0u32; 3 * 2];
        for (lo, hi) in [(0, 5), (5, 8)] {
            pc.set_window(lo, hi);
            assert!(pc.push_level(0, &p0, 3));
            pc.accumulate_window(1, &node, 2, &mut merged);
        }
        assert_eq!(whole, merged);
        assert_eq!(whole.iter().sum::<u32>(), 8);
    }
}
