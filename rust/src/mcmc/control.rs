//! Shared chain control: a cooperative cancellation flag polled
//! between MH steps, plus lock-free progress counters the service
//! daemon's event stream and the CLI's Ctrl-C handler read while
//! chains run.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Capacity of each chain's rolling score window (recent post-step
/// scores): big enough for PSRF/ESS to stabilize, small enough that a
/// window is a few KB.
pub const ROLLING_WINDOW: usize = 512;

/// A rolling window of one chain's recent post-step scores, feeding
/// the live PSRF/ESS telemetry gauges. Single writer (the chain), any
/// number of snapshot readers; a small mutex-guarded ring, locked once
/// per MH step by the writer.
///
/// Like the progress counters, windows are **telemetry only**: nothing
/// the chain computes ever reads them back.
#[derive(Debug, Default)]
pub struct ScoreWindow {
    ring: Mutex<Ring>,
}

#[derive(Debug, Default)]
struct Ring {
    buf: Vec<f64>,
    total: u64,
}

impl ScoreWindow {
    /// Record a post-step score (overwrites the oldest entry once the
    /// window is full).
    pub fn record(&self, score: f64) {
        let mut ring = self.ring.lock().expect("score window lock poisoned");
        if ring.buf.len() < ROLLING_WINDOW {
            ring.buf.push(score);
        } else {
            let pos = (ring.total % ROLLING_WINDOW as u64) as usize;
            ring.buf[pos] = score;
        }
        ring.total += 1;
    }

    /// Scores recorded so far (including overwritten ones).
    pub fn total(&self) -> u64 {
        self.ring.lock().expect("score window lock poisoned").total
    }

    /// The window contents, oldest first.
    pub fn snapshot(&self) -> Vec<f64> {
        let ring = self.ring.lock().expect("score window lock poisoned");
        if ring.total <= ROLLING_WINDOW as u64 {
            ring.buf.clone()
        } else {
            let pos = (ring.total % ROLLING_WINDOW as u64) as usize;
            let mut out = Vec::with_capacity(ROLLING_WINDOW);
            out.extend_from_slice(&ring.buf[pos..]);
            out.extend_from_slice(&ring.buf[..pos]);
            out
        }
    }
}

/// Control/telemetry block shared between a controller (the one-shot
/// CLI's Ctrl-C handler, the service daemon's `cancel` endpoint) and
/// the chains of one run.
///
/// Cancellation is **cooperative and step-granular**: chains poll the
/// flag between MH steps, so no step is ever torn mid-transition and a
/// cancelled chain's state is exactly the state after its last
/// completed step — checkpointable and resumable. The posterior
/// sampler additionally rolls a cancelled run back to its last
/// checkpoint-segment boundary so the chains stay iteration-aligned
/// (see `posterior::sampler`).
///
/// The counters are `Relaxed` telemetry: they sum steps across every
/// chain sharing the block and may lag the true totals by in-flight
/// steps, but they never participate in any trajectory decision.
#[derive(Debug, Default)]
pub struct ChainControl {
    cancel: AtomicBool,
    /// MH steps completed across all chains sharing this block.
    pub iterations: AtomicU64,
    /// Accepted proposals across all chains sharing this block.
    pub accepted: AtomicU64,
    /// Rolling score windows, one per chain index (see
    /// [`Self::window`]); read by the live PSRF/ESS diagnostics.
    windows: Mutex<Vec<Arc<ScoreWindow>>>,
}

impl ChainControl {
    /// A fresh, uncancelled control block behind the [`Arc`] every
    /// consumer (chain spec, sampler options, watcher thread) clones.
    pub fn shared() -> Arc<Self> {
        Arc::default()
    }

    /// Ask every chain sharing this block to stop before its next step.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    /// True once [`Self::cancel`] has been called.
    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::SeqCst)
    }

    /// Progress snapshot `(iterations, accepted)`.
    pub fn progress(&self) -> (u64, u64) {
        (self.iterations.load(Ordering::Relaxed), self.accepted.load(Ordering::Relaxed))
    }

    /// Fold one completed step into the shared counters.
    pub(crate) fn count_step(&self, accepted: bool) {
        self.iterations.fetch_add(1, Ordering::Relaxed);
        if accepted {
            self.accepted.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The rolling score window of chain `chain` (created on first
    /// use). Keyed by index so a checkpoint-segmented run's chain `c`
    /// keeps appending to the same window across segments.
    pub fn window(&self, chain: usize) -> Arc<ScoreWindow> {
        let mut windows = self.windows.lock().expect("windows lock poisoned");
        while windows.len() <= chain {
            windows.push(Arc::new(ScoreWindow::default()));
        }
        windows[chain].clone()
    }

    /// Snapshot every chain's rolling score window, oldest first per
    /// chain (empty for chains that have not stepped yet).
    pub fn rolling_traces(&self) -> Vec<Vec<f64>> {
        let windows = self.windows.lock().expect("windows lock poisoned");
        windows.iter().map(|w| w.snapshot()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_clear_and_latches() {
        let control = ChainControl::shared();
        assert!(!control.is_cancelled());
        assert_eq!(control.progress(), (0, 0));
        control.cancel();
        assert!(control.is_cancelled());
        control.cancel(); // idempotent
        assert!(control.is_cancelled());
    }

    #[test]
    fn counts_steps_across_clones() {
        let control = ChainControl::shared();
        let other = control.clone();
        control.count_step(true);
        other.count_step(false);
        other.count_step(true);
        assert_eq!(control.progress(), (3, 2));
    }
}
