//! Shared chain control: a cooperative cancellation flag polled
//! between MH steps, plus lock-free progress counters the service
//! daemon's event stream and the CLI's Ctrl-C handler read while
//! chains run.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Control/telemetry block shared between a controller (the one-shot
/// CLI's Ctrl-C handler, the service daemon's `cancel` endpoint) and
/// the chains of one run.
///
/// Cancellation is **cooperative and step-granular**: chains poll the
/// flag between MH steps, so no step is ever torn mid-transition and a
/// cancelled chain's state is exactly the state after its last
/// completed step — checkpointable and resumable. The posterior
/// sampler additionally rolls a cancelled run back to its last
/// checkpoint-segment boundary so the chains stay iteration-aligned
/// (see `posterior::sampler`).
///
/// The counters are `Relaxed` telemetry: they sum steps across every
/// chain sharing the block and may lag the true totals by in-flight
/// steps, but they never participate in any trajectory decision.
#[derive(Debug, Default)]
pub struct ChainControl {
    cancel: AtomicBool,
    /// MH steps completed across all chains sharing this block.
    pub iterations: AtomicU64,
    /// Accepted proposals across all chains sharing this block.
    pub accepted: AtomicU64,
}

impl ChainControl {
    /// A fresh, uncancelled control block behind the [`Arc`] every
    /// consumer (chain spec, sampler options, watcher thread) clones.
    pub fn shared() -> Arc<Self> {
        Arc::default()
    }

    /// Ask every chain sharing this block to stop before its next step.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    /// True once [`Self::cancel`] has been called.
    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::SeqCst)
    }

    /// Progress snapshot `(iterations, accepted)`.
    pub fn progress(&self) -> (u64, u64) {
        (self.iterations.load(Ordering::Relaxed), self.accepted.load(Ordering::Relaxed))
    }

    /// Fold one completed step into the shared counters.
    pub(crate) fn count_step(&self, accepted: bool) {
        self.iterations.fetch_add(1, Ordering::Relaxed);
        if accepted {
            self.accepted.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_clear_and_latches() {
        let control = ChainControl::shared();
        assert!(!control.is_cancelled());
        assert_eq!(control.progress(), (0, 0));
        control.cancel();
        assert!(control.is_cancelled());
        control.cancel(); // idempotent
        assert!(control.is_cancelled());
    }

    #[test]
    fn counts_steps_across_clones() {
        let control = ChainControl::shared();
        let other = control.clone();
        control.count_step(true);
        other.count_step(false);
        other.count_step(true);
        assert_eq!(control.progress(), (3, 2));
    }
}
