//! Best-graph tracking (Section III-C): "we keep track of a number of
//! best graphs obtained so far as the sampling procedure proceeds."

use crate::bn::Dag;

/// Top-k graphs by score, deduplicated by structure.
#[derive(Debug, Clone)]
pub struct BestGraphTracker {
    capacity: usize,
    /// Sorted descending by score.
    entries: Vec<(f64, Dag)>,
}

impl BestGraphTracker {
    /// Track the best `capacity` distinct graphs.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        BestGraphTracker { capacity, entries: Vec::with_capacity(capacity + 1) }
    }

    /// Offer a scored graph; returns `true` if it entered the top-k.
    ///
    /// Inserts in place at the score's slot (binary search over the
    /// descending list) — the old implementation re-sorted the whole
    /// top-k on every hit, and its `partial_cmp(..).unwrap()` panicked
    /// on NaN scores instead of ordering them.
    pub fn offer(&mut self, score: f64, graph: &Dag) -> bool {
        if score.is_nan() {
            return false; // a NaN score can never be a "best" graph
        }
        if let Some(pos) = self.entries.iter().position(|(_, g)| g == graph) {
            // Same structure seen before — keep the better score.
            if score > self.entries[pos].0 {
                let (_, dag) = self.entries.remove(pos);
                let at = self.insertion_point(score);
                self.entries.insert(at, (score, dag));
                return true;
            }
            return false;
        }
        if self.entries.len() < self.capacity {
            let at = self.insertion_point(score);
            self.entries.insert(at, (score, graph.clone()));
            return true;
        }
        if score > self.entries.last().unwrap().0 {
            self.entries.pop();
            let at = self.insertion_point(score);
            self.entries.insert(at, (score, graph.clone()));
            return true;
        }
        false
    }

    /// First index whose score falls strictly below `score` in the
    /// descending entry list (NaN-safe total order; equal scores keep
    /// earlier entries first).
    fn insertion_point(&self, score: f64) -> usize {
        self.entries.partition_point(|(s, _)| s.total_cmp(&score).is_ge())
    }

    /// Best (score, graph), if any was offered.
    pub fn best(&self) -> Option<&(f64, Dag)> {
        self.entries.first()
    }

    /// All tracked entries, best first.
    pub fn entries(&self) -> &[(f64, Dag)] {
        &self.entries
    }

    /// Rebuild a tracker from saved entries (checkpoint restore).
    /// Offering in saved best-first order reproduces the entry list.
    pub fn from_entries(capacity: usize, entries: Vec<(f64, Dag)>) -> Self {
        let mut tracker = BestGraphTracker::new(capacity);
        for (score, graph) in &entries {
            tracker.offer(*score, graph);
        }
        tracker
    }

    /// Merge another tracker into this one (multi-chain reduction).
    pub fn merge(&mut self, other: &BestGraphTracker) {
        for (score, graph) in &other.entries {
            self.offer(*score, graph);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(edges: &[(usize, usize)]) -> Dag {
        Dag::from_edges(4, edges)
    }

    #[test]
    fn keeps_topk_sorted() {
        let mut t = BestGraphTracker::new(2);
        assert!(t.offer(-10.0, &g(&[(0, 1)])));
        assert!(t.offer(-5.0, &g(&[(1, 2)])));
        assert!(t.offer(-7.0, &g(&[(2, 3)])));
        assert_eq!(t.entries().len(), 2);
        assert_eq!(t.entries()[0].0, -5.0);
        assert_eq!(t.entries()[1].0, -7.0);
        assert!(!t.offer(-20.0, &g(&[(0, 3)])));
    }

    #[test]
    fn dedups_same_structure() {
        let mut t = BestGraphTracker::new(3);
        t.offer(-10.0, &g(&[(0, 1)]));
        t.offer(-8.0, &g(&[(0, 1)])); // same graph, better score
        assert_eq!(t.entries().len(), 1);
        assert_eq!(t.best().unwrap().0, -8.0);
        assert!(!t.offer(-9.0, &g(&[(0, 1)]))); // same graph, worse
        assert_eq!(t.best().unwrap().0, -8.0);
    }

    #[test]
    fn merge_combines_chains() {
        let mut a = BestGraphTracker::new(2);
        a.offer(-10.0, &g(&[(0, 1)]));
        let mut b = BestGraphTracker::new(2);
        b.offer(-5.0, &g(&[(1, 2)]));
        b.offer(-3.0, &g(&[(2, 3)]));
        a.merge(&b);
        assert_eq!(a.best().unwrap().0, -3.0);
        assert_eq!(a.entries().len(), 2);
    }

    #[test]
    fn from_entries_roundtrips() {
        let mut t = BestGraphTracker::new(3);
        t.offer(-10.0, &g(&[(0, 1)]));
        t.offer(-5.0, &g(&[(1, 2)]));
        t.offer(-7.0, &g(&[(2, 3)]));
        let rebuilt = BestGraphTracker::from_entries(3, t.entries().to_vec());
        assert_eq!(rebuilt.entries(), t.entries());
    }

    #[test]
    fn empty_tracker() {
        let t = BestGraphTracker::new(1);
        assert!(t.best().is_none());
    }

    /// A NaN score must not panic the tracker (the old
    /// `partial_cmp(..).unwrap()` sort did) and must never enter the
    /// top-k.
    #[test]
    fn nan_scores_do_not_panic_or_win() {
        let mut t = BestGraphTracker::new(2);
        assert!(!t.offer(f64::NAN, &g(&[(0, 1)])));
        t.offer(-5.0, &g(&[(1, 2)]));
        t.offer(-7.0, &g(&[(2, 3)]));
        assert!(!t.offer(f64::NAN, &g(&[(0, 2)])));
        assert!(!t.offer(f64::NAN, &g(&[(1, 2)]))); // known structure, NaN rescore
        assert_eq!(t.best().unwrap().0, -5.0);
        assert_eq!(t.entries().len(), 2);
    }

    /// The in-place insert keeps the list identical to what a full
    /// re-sort produced, across a randomized offer stream.
    #[test]
    fn insertion_matches_sorted_order() {
        let mut t = BestGraphTracker::new(4);
        let mut state = 0x9E37u64;
        for i in 0..200u64 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let score = -((state >> 40) as f64) / 1e3;
            let from = (i % 3) as usize;
            let to = 3usize.min(from + 1 + (state % 2) as usize);
            t.offer(score, &g(&[(from, to)]));
            let scores: Vec<f64> = t.entries().iter().map(|(s, _)| *s).collect();
            assert!(scores.windows(2).all(|w| w[0] >= w[1]), "unsorted: {scores:?}");
        }
        assert!(t.entries().len() <= 4);
    }
}
