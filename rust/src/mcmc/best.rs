//! Best-graph tracking (Section III-C): "we keep track of a number of
//! best graphs obtained so far as the sampling procedure proceeds."

use crate::bn::Dag;

/// Top-k graphs by score, deduplicated by structure.
#[derive(Debug, Clone)]
pub struct BestGraphTracker {
    capacity: usize,
    /// Sorted descending by score.
    entries: Vec<(f64, Dag)>,
}

impl BestGraphTracker {
    /// Track the best `capacity` distinct graphs.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        BestGraphTracker { capacity, entries: Vec::with_capacity(capacity + 1) }
    }

    /// Offer a scored graph; returns `true` if it entered the top-k.
    pub fn offer(&mut self, score: f64, graph: &Dag) -> bool {
        if let Some(pos) = self.entries.iter().position(|(_, g)| g == graph) {
            // Same structure seen before — keep the better score.
            if score > self.entries[pos].0 {
                self.entries[pos].0 = score;
                self.entries.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                return true;
            }
            return false;
        }
        if self.entries.len() < self.capacity {
            self.entries.push((score, graph.clone()));
            self.entries.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            return true;
        }
        if score > self.entries.last().unwrap().0 {
            self.entries.pop();
            self.entries.push((score, graph.clone()));
            self.entries.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            return true;
        }
        false
    }

    /// Best (score, graph), if any was offered.
    pub fn best(&self) -> Option<&(f64, Dag)> {
        self.entries.first()
    }

    /// All tracked entries, best first.
    pub fn entries(&self) -> &[(f64, Dag)] {
        &self.entries
    }

    /// Rebuild a tracker from saved entries (checkpoint restore).
    /// Offering in saved best-first order reproduces the entry list.
    pub fn from_entries(capacity: usize, entries: Vec<(f64, Dag)>) -> Self {
        let mut tracker = BestGraphTracker::new(capacity);
        for (score, graph) in &entries {
            tracker.offer(*score, graph);
        }
        tracker
    }

    /// Merge another tracker into this one (multi-chain reduction).
    pub fn merge(&mut self, other: &BestGraphTracker) {
        for (score, graph) in &other.entries {
            self.offer(*score, graph);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(edges: &[(usize, usize)]) -> Dag {
        Dag::from_edges(4, edges)
    }

    #[test]
    fn keeps_topk_sorted() {
        let mut t = BestGraphTracker::new(2);
        assert!(t.offer(-10.0, &g(&[(0, 1)])));
        assert!(t.offer(-5.0, &g(&[(1, 2)])));
        assert!(t.offer(-7.0, &g(&[(2, 3)])));
        assert_eq!(t.entries().len(), 2);
        assert_eq!(t.entries()[0].0, -5.0);
        assert_eq!(t.entries()[1].0, -7.0);
        assert!(!t.offer(-20.0, &g(&[(0, 3)])));
    }

    #[test]
    fn dedups_same_structure() {
        let mut t = BestGraphTracker::new(3);
        t.offer(-10.0, &g(&[(0, 1)]));
        t.offer(-8.0, &g(&[(0, 1)])); // same graph, better score
        assert_eq!(t.entries().len(), 1);
        assert_eq!(t.best().unwrap().0, -8.0);
        assert!(!t.offer(-9.0, &g(&[(0, 1)]))); // same graph, worse
        assert_eq!(t.best().unwrap().0, -8.0);
    }

    #[test]
    fn merge_combines_chains() {
        let mut a = BestGraphTracker::new(2);
        a.offer(-10.0, &g(&[(0, 1)]));
        let mut b = BestGraphTracker::new(2);
        b.offer(-5.0, &g(&[(1, 2)]));
        b.offer(-3.0, &g(&[(2, 3)]));
        a.merge(&b);
        assert_eq!(a.best().unwrap().0, -3.0);
        assert_eq!(a.entries().len(), 2);
    }

    #[test]
    fn from_entries_roundtrips() {
        let mut t = BestGraphTracker::new(3);
        t.offer(-10.0, &g(&[(0, 1)]));
        t.offer(-5.0, &g(&[(1, 2)]));
        t.offer(-7.0, &g(&[(2, 3)]));
        let rebuilt = BestGraphTracker::from_entries(3, t.entries().to_vec());
        assert_eq!(rebuilt.entries(), t.entries());
    }

    #[test]
    fn empty_tracker() {
        let t = BestGraphTracker::new(1);
        assert!(t.best().is_none());
    }
}
