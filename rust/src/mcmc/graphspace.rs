//! Graph-space MCMC baseline — the sampler the paper's Section II argues
//! *against* ("order sampling is demonstrated to be the best one").
//!
//! A Metropolis–Hastings random walk directly over DAGs: propose an edge
//! addition, deletion, or reversal; reject cycle-creating or
//! degree-violating proposals; accept by the BDe score ratio (only the
//! affected nodes' local scores change, fetched from the same
//! preprocessed table). Used by the sampler-comparison ablation to show
//! why the order space converges in far fewer steps (Table I's
//! graphs-vs-orders count gap made operational).

use crate::bn::Dag;
use crate::mcmc::best::BestGraphTracker;
use crate::score::ScoreTable;
use crate::util::Pcg32;

/// One proposed structural move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Move {
    Add(usize, usize),
    Delete(usize, usize),
    Reverse(usize, usize),
}

/// Graph-space MH chain over the bounded-parent-set hypothesis space.
///
/// Deliberately **dense-table only** (not generic over `ScoreStore`):
/// unlike the order engines' one-shot max scan, where dominance pruning
/// is exact, this incremental walk moves *through* intermediate parent
/// sets — a pruned (dominated) intermediate would read back as the
/// sentinel and be rejected with probability 1, silently changing the
/// sampled distribution and blocking single-edge paths to sets whose
/// intermediates are dominated.
pub struct GraphChain<'a> {
    table: &'a ScoreTable,
    dag: Dag,
    /// Per-node local scores of the current graph.
    node_scores: Vec<f64>,
    current: f64,
    pub tracker: BestGraphTracker,
    pub iterations: u64,
    pub accepted: u64,
    rng: Pcg32,
}

impl<'a> GraphChain<'a> {
    /// Start from the empty graph.
    pub fn new(table: &'a ScoreTable, topk: usize, seed: u64) -> Self {
        let n = table.n();
        let dag = Dag::empty(n);
        let node_scores: Vec<f64> =
            (0..n).map(|i| table.score_of(i, &[]) as f64).collect();
        let current = node_scores.iter().sum();
        let mut tracker = BestGraphTracker::new(topk);
        tracker.offer(current, &dag);
        GraphChain {
            table,
            dag,
            node_scores,
            current,
            tracker,
            iterations: 0,
            accepted: 0,
            rng: Pcg32::new(seed),
        }
    }

    /// Current total score.
    pub fn current_score(&self) -> f64 {
        self.current
    }

    /// Current structure.
    pub fn dag(&self) -> &Dag {
        &self.dag
    }

    fn propose(&mut self) -> Move {
        let n = self.dag.n();
        loop {
            let from = self.rng.gen_range(n);
            let to = self.rng.gen_range(n);
            if from == to {
                continue;
            }
            if self.dag.has_edge(from, to) {
                return if self.rng.gen_bool(0.5) {
                    Move::Delete(from, to)
                } else {
                    Move::Reverse(from, to)
                };
            }
            return Move::Add(from, to);
        }
    }

    /// Local score of `node` with `parents` modified by the closure.
    fn rescored(&self, node: usize, edit: impl FnOnce(&mut Vec<usize>)) -> Option<f64> {
        let mut parents = self.dag.parents(node).to_vec();
        edit(&mut parents);
        parents.sort_unstable();
        if parents.len() > self.table.s() {
            return None; // outside the bounded hypothesis space
        }
        Some(self.table.score_of(node, &parents) as f64)
    }

    /// One MH step; returns true on acceptance.
    pub fn step(&mut self) -> bool {
        self.iterations += 1;
        let mv = self.propose();

        // Compute the score delta over the affected nodes, validating
        // acyclicity on a scratch copy (n ≤ 64 — clone is cheap relative
        // to scoring).
        let mut candidate = self.dag.clone();
        let (changed, new_scores): (Vec<usize>, Vec<f64>) = match mv {
            Move::Add(from, to) => {
                let Some(score) = self.rescored(to, |ps| ps.push(from)) else {
                    return false;
                };
                let mut ps = candidate.parents(to).to_vec();
                ps.push(from);
                candidate.set_parents(to, ps);
                if !candidate.is_acyclic() {
                    return false;
                }
                (vec![to], vec![score])
            }
            Move::Delete(from, to) => {
                let Some(score) = self.rescored(to, |ps| ps.retain(|&m| m != from)) else {
                    return false;
                };
                let mut ps = candidate.parents(to).to_vec();
                ps.retain(|&m| m != from);
                candidate.set_parents(to, ps);
                (vec![to], vec![score])
            }
            Move::Reverse(from, to) => {
                let Some(s_to) = self.rescored(to, |ps| ps.retain(|&m| m != from)) else {
                    return false;
                };
                let Some(s_from) = self.rescored(from, |ps| ps.push(to)) else {
                    return false;
                };
                let mut ps = candidate.parents(to).to_vec();
                ps.retain(|&m| m != from);
                candidate.set_parents(to, ps);
                let mut ps = candidate.parents(from).to_vec();
                ps.push(to);
                candidate.set_parents(from, ps);
                if !candidate.is_acyclic() {
                    return false;
                }
                (vec![to, from], vec![s_to, s_from])
            }
        };

        let mut proposed = self.current;
        for (&node, &score) in changed.iter().zip(&new_scores) {
            proposed += score - self.node_scores[node];
        }
        let log_u = self.rng.gen_f64_open().ln();
        if log_u < (proposed - self.current) * std::f64::consts::LN_10 {
            self.dag = candidate;
            for (&node, &score) in changed.iter().zip(&new_scores) {
                self.node_scores[node] = score;
            }
            self.current = proposed;
            self.accepted += 1;
            self.tracker.offer(self.current, &self.dag);
            true
        } else {
            false
        }
    }

    /// Run `iters` steps.
    pub fn run(&mut self, iters: u64) {
        for _ in 0..iters {
            self.step();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scorer::testutil::fixture;

    #[test]
    fn chain_stays_acyclic_and_bounded() {
        let (_, table) = fixture(8, 3, 200, 201);
        let mut chain = GraphChain::new(&table, 2, 202);
        chain.run(500);
        assert!(chain.dag().is_acyclic());
        assert!(chain.dag().max_in_degree() <= 3);
        assert!(chain.accepted > 0);
    }

    #[test]
    fn current_score_matches_table_sum() {
        let (_, table) = fixture(6, 2, 150, 203);
        let mut chain = GraphChain::new(&table, 1, 204);
        chain.run(300);
        let direct: f64 =
            (0..6).map(|i| table.score_of(i, chain.dag().parents(i)) as f64).sum();
        assert!((chain.current_score() - direct).abs() < 1e-6);
    }

    #[test]
    fn graph_chain_improves_over_empty() {
        let (_, table) = fixture(7, 3, 300, 205);
        let empty_score: f64 = (0..7).map(|i| table.score_of(i, &[]) as f64).sum();
        let mut chain = GraphChain::new(&table, 1, 206);
        chain.run(2000);
        assert!(chain.tracker.best().unwrap().0 >= empty_score);
    }

    #[test]
    fn order_sampler_converges_faster_than_graph_sampler() {
        // The paper's Section II argument, operational: same budget of
        // scored candidates, order space reaches a better graph.
        let (_, table) = fixture(10, 3, 400, 207);
        let budget = 300u64;
        let mut graph_chain = GraphChain::new(&table, 1, 208);
        graph_chain.run(budget * 10); // even with 10x the steps...
        let graph_best = graph_chain.tracker.best().unwrap().0;

        let mut scorer = crate::scorer::SerialScorer::new(&table);
        let order_best =
            crate::mcmc::run_chain(&mut scorer, 10, budget, 1, 209).best_score().unwrap();
        assert!(
            order_best >= graph_best - 1e-6,
            "order {order_best} < graph {graph_best}"
        );
    }
}
