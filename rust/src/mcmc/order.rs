//! Topological-order state for the sampler.
//!
//! Maintains both directions of the permutation: `seq[k]` = node at
//! position k, and `pos[v]` = position of node v. The position vector is
//! what the scoring engines consume (and the only thing re-uploaded to
//! the accelerator each iteration).

use crate::util::Pcg32;

/// A total order over `n` nodes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Order {
    seq: Vec<usize>,
    pos: Vec<usize>,
}

impl Order {
    /// Identity order `0, 1, …, n-1`.
    pub fn identity(n: usize) -> Self {
        Order { seq: (0..n).collect(), pos: (0..n).collect() }
    }

    /// Uniformly random order (the paper's order initialization).
    pub fn random(n: usize, rng: &mut Pcg32) -> Self {
        let seq = rng.permutation(n);
        Order::from_seq(seq)
    }

    /// Build from an explicit sequence (`seq[k]` = node at position k).
    pub fn from_seq(seq: Vec<usize>) -> Self {
        let n = seq.len();
        let mut pos = vec![usize::MAX; n];
        for (k, &v) in seq.iter().enumerate() {
            assert!(v < n && pos[v] == usize::MAX, "not a permutation");
            pos[v] = k;
        }
        Order { seq, pos }
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.seq.len()
    }

    /// `seq[k]` = node at position k.
    pub fn seq(&self) -> &[usize] {
        &self.seq
    }

    /// `pos[v]` = position of node v.
    pub fn pos(&self) -> &[usize] {
        &self.pos
    }

    /// Position vector as i32 (the accelerator input layout).
    pub fn pos_i32(&self) -> Vec<i32> {
        self.pos.iter().map(|&p| p as i32).collect()
    }

    /// Swap the nodes at positions `a` and `b` (the paper's proposal move).
    pub fn swap_positions(&mut self, a: usize, b: usize) {
        let (va, vb) = (self.seq[a], self.seq[b]);
        self.seq.swap(a, b);
        self.pos[va] = b;
        self.pos[vb] = a;
    }

    /// Nodes preceding position `p`, i.e. the candidate parents of
    /// `seq[p]` — sorted by node id (the layout order scorers need).
    pub fn predecessors_sorted(&self, p: usize) -> Vec<usize> {
        let mut preds: Vec<usize> = self.seq[..p].to_vec();
        preds.sort_unstable();
        preds
    }

    /// Invariant check (tests / debug).
    pub fn check(&self) -> bool {
        self.seq.len() == self.pos.len()
            && self.seq.iter().enumerate().all(|(k, &v)| self.pos[v] == k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_and_random_are_valid() {
        assert!(Order::identity(7).check());
        let mut rng = Pcg32::new(61);
        for _ in 0..50 {
            assert!(Order::random(9, &mut rng).check());
        }
    }

    #[test]
    fn swap_maintains_inverse() {
        let mut o = Order::identity(6);
        o.swap_positions(1, 4);
        assert!(o.check());
        assert_eq!(o.seq()[1], 4);
        assert_eq!(o.seq()[4], 1);
        assert_eq!(o.pos()[4], 1);
        // swap back restores
        o.swap_positions(1, 4);
        assert_eq!(o, Order::identity(6));
    }

    #[test]
    fn swap_same_position_is_noop() {
        let mut o = Order::identity(5);
        o.swap_positions(2, 2);
        assert_eq!(o, Order::identity(5));
    }

    #[test]
    fn random_swap_walk_stays_valid() {
        let mut rng = Pcg32::new(62);
        let mut o = Order::random(12, &mut rng);
        for _ in 0..500 {
            let a = rng.gen_range(12);
            let b = rng.gen_range(12);
            o.swap_positions(a, b);
            assert!(o.check());
        }
    }

    #[test]
    fn predecessors_are_sorted_prefix() {
        let o = Order::from_seq(vec![3, 1, 4, 0, 2]);
        assert_eq!(o.predecessors_sorted(0), Vec::<usize>::new());
        assert_eq!(o.predecessors_sorted(3), vec![1, 3, 4]);
        assert_eq!(o.predecessors_sorted(5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "permutation")]
    fn rejects_non_permutation() {
        Order::from_seq(vec![0, 0, 1]);
    }
}
