//! Chain drivers: single-chain runs and the multi-chain parallel runner
//! (independent chains on a thread pool, merged by the best-graph
//! reduction — the natural extension the paper's Section II motivates
//! with "sampling in order space provides opportunities for parallel
//! implementation").

use super::best::BestGraphTracker;
use super::chain::{ChainStats, McmcChain};
use crate::bn::Dag;
use crate::scorer::OrderScorer;
use crate::util::Timer;

/// Outcome of a learning run.
#[derive(Debug, Clone)]
pub struct LearnResult {
    /// Best graphs found (best first) with their scores.
    pub best: Vec<(f64, Dag)>,
    /// Aggregated chain statistics.
    pub stats: ChainStats,
    /// Wall-clock seconds spent sampling (excludes preprocessing).
    pub sampling_secs: f64,
    /// Number of chains run.
    pub chains: usize,
}

impl LearnResult {
    /// The single best graph.
    pub fn best_dag(&self) -> &Dag {
        &self.best.first().expect("no graphs tracked").1
    }

    /// The best score.
    pub fn best_score(&self) -> f64 {
        self.best.first().expect("no graphs tracked").0
    }
}

/// Run one chain for `iters` iterations.
pub fn run_chain<S: OrderScorer + ?Sized>(
    scorer: &mut S,
    n: usize,
    iters: u64,
    topk: usize,
    seed: u64,
) -> LearnResult {
    let timer = Timer::start();
    let mut chain = McmcChain::new(scorer, n, topk, seed);
    chain.run(iters);
    LearnResult {
        best: chain.tracker.entries().to_vec(),
        stats: chain.stats.clone(),
        sampling_secs: timer.elapsed_secs(),
        chains: 1,
    }
}

/// Run `chains` independent chains in parallel, each built from
/// `make_scorer(chain_id)` on its own thread, and merge the trackers.
///
/// The factory runs *on the worker thread*, so non-`Send` engines (e.g.
/// an engine holding PJRT handles) can still be used with `chains = 1`;
/// for >1 chains the factory itself must be `Sync`.
pub fn run_chains_parallel<F, S>(
    make_scorer: F,
    n: usize,
    iters: u64,
    topk: usize,
    seed: u64,
    chains: usize,
) -> LearnResult
where
    F: Fn(usize) -> S + Sync,
    S: OrderScorer,
{
    assert!(chains >= 1);
    let timer = Timer::start();
    let results: Vec<(BestGraphTracker, ChainStats)> = std::thread::scope(|scope| {
        let make_scorer = &make_scorer;
        let handles: Vec<_> = (0..chains)
            .map(|c| {
                scope.spawn(move || {
                    let mut scorer = make_scorer(c);
                    let mut chain =
                        McmcChain::new(&mut scorer, n, topk, seed.wrapping_add(c as u64 * 0x9E37));
                    chain.run(iters);
                    (chain.tracker.clone(), chain.stats.clone())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("chain panicked")).collect()
    });

    let mut merged = BestGraphTracker::new(topk);
    let mut stats = ChainStats::default();
    for (tracker, s) in &results {
        merged.merge(tracker);
        stats.iterations += s.iterations;
        stats.accepted += s.accepted;
    }
    LearnResult {
        best: merged.entries().to_vec(),
        stats,
        sampling_secs: timer.elapsed_secs(),
        chains,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scorer::testutil::fixture;
    use crate::scorer::SerialScorer;

    #[test]
    fn single_chain_returns_graphs() {
        let (_, table) = fixture(7, 3, 200, 121);
        let mut scorer = SerialScorer::new(&table);
        let res = run_chain(&mut scorer, 7, 200, 3, 122);
        assert!(!res.best.is_empty());
        assert!(res.best_score().is_finite());
        assert!(res.sampling_secs > 0.0);
        // entries sorted descending
        for w in res.best.windows(2) {
            assert!(w[0].0 >= w[1].0);
        }
    }

    #[test]
    fn parallel_chains_at_least_match_single() {
        let (_, table) = fixture(7, 3, 200, 123);
        let single = {
            let mut scorer = SerialScorer::new(&table);
            run_chain(&mut scorer, 7, 300, 1, 42)
        };
        let multi = run_chains_parallel(|_| SerialScorer::new(&table), 7, 300, 1, 42, 4);
        // 4 chains including the same seed as the single run ⇒ can't do worse
        assert!(multi.best_score() >= single.best_score() - 1e-9);
        assert_eq!(multi.stats.iterations, 4 * 300);
        assert_eq!(multi.chains, 4);
    }

    #[test]
    fn parallel_is_deterministic() {
        let (_, table) = fixture(6, 2, 150, 124);
        let a = run_chains_parallel(|_| SerialScorer::new(&table), 6, 100, 2, 7, 3);
        let b = run_chains_parallel(|_| SerialScorer::new(&table), 6, 100, 2, 7, 3);
        assert_eq!(a.best_score(), b.best_score());
        assert_eq!(a.stats.accepted, b.stats.accepted);
    }
}
