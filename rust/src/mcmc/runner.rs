//! Chain drivers: single-chain runs and the multi-chain parallel runner
//! (independent chains on a thread pool, merged by the best-graph
//! reduction — the natural extension the paper's Section II motivates
//! with "sampling in order space provides opportunities for parallel
//! implementation").

use std::sync::Arc;

use super::best::BestGraphTracker;
use super::chain::{ChainStats, McmcChain, ProposalKind};
use super::control::ChainControl;
use crate::bn::Dag;
use crate::scorer::OrderScorer;
use crate::util::Timer;

/// Knobs of a chain run, bundled so drivers don't grow endless
/// positional parameters. The classic `run_chain*` entry points are thin
/// wrappers over the `*_spec` cores with default proposal/trace settings.
#[derive(Debug, Clone)]
pub struct ChainSpec {
    /// Node count.
    pub n: usize,
    /// Iterations per chain.
    pub iters: u64,
    /// Best-graph tracker capacity.
    pub topk: usize,
    /// Master seed (chain c derives `seed + c · 0x9E37`).
    pub seed: u64,
    /// Independent chains (parallel runner only).
    pub chains: usize,
    /// Record per-iteration score traces.
    pub record_trace: bool,
    /// Proposal move (see [`ProposalKind`]).
    pub proposal: ProposalKind,
    /// Shared cancellation flag + progress counters attached to every
    /// chain of the run (see [`ChainControl`]); `None` runs uncontrolled.
    pub control: Option<Arc<ChainControl>>,
}

impl ChainSpec {
    /// Defaults: one chain, no trace, uniform swap proposals, no control.
    pub fn new(n: usize, iters: u64, topk: usize, seed: u64) -> Self {
        ChainSpec {
            n,
            iters,
            topk,
            seed,
            chains: 1,
            record_trace: false,
            proposal: ProposalKind::Swap,
            control: None,
        }
    }
}

/// Outcome of a learning run.
#[derive(Debug, Clone)]
pub struct LearnResult {
    /// Best graphs found (best first) with their scores.
    pub best: Vec<(f64, Dag)>,
    /// Aggregated chain statistics (the per-chain traces live in
    /// [`Self::traces`], so the aggregate's `trace` stays empty for
    /// multi-chain runs).
    pub stats: ChainStats,
    /// Per-chain score traces (empty unless trace recording was on) —
    /// the raw material of the PSRF/ESS convergence diagnostics.
    pub traces: Vec<Vec<f64>>,
    /// Wall-clock seconds spent sampling (excludes preprocessing).
    pub sampling_secs: f64,
    /// Number of chains run.
    pub chains: usize,
}

impl LearnResult {
    /// The single best graph, if any iteration tracked one (a
    /// zero-iteration run tracks nothing).
    pub fn best_dag(&self) -> Option<&Dag> {
        self.best.first().map(|(_, dag)| dag)
    }

    /// The best score, if any graph was tracked.
    pub fn best_score(&self) -> Option<f64> {
        self.best.first().map(|(score, _)| *score)
    }
}

/// Run one chain for `iters` iterations.
pub fn run_chain<S: OrderScorer + ?Sized>(
    scorer: &mut S,
    n: usize,
    iters: u64,
    topk: usize,
    seed: u64,
) -> LearnResult {
    run_chain_traced(scorer, n, iters, topk, seed, false)
}

/// [`run_chain`] with optional per-iteration score-trace recording.
pub fn run_chain_traced<S: OrderScorer + ?Sized>(
    scorer: &mut S,
    n: usize,
    iters: u64,
    topk: usize,
    seed: u64,
    record_trace: bool,
) -> LearnResult {
    let mut spec = ChainSpec::new(n, iters, topk, seed);
    spec.record_trace = record_trace;
    run_chain_spec(scorer, &spec)
}

/// Run one chain as described by `spec` (`spec.chains` is ignored here).
pub fn run_chain_spec<S: OrderScorer + ?Sized>(scorer: &mut S, spec: &ChainSpec) -> LearnResult {
    let timer = Timer::start();
    let mut chain = McmcChain::new(scorer, spec.n, spec.topk, spec.seed);
    chain.set_proposal(spec.proposal);
    chain.set_record_trace(spec.record_trace);
    if let Some(control) = &spec.control {
        chain.set_control(control.clone());
    }
    chain.run(spec.iters);
    let traces = if spec.record_trace { vec![chain.stats.trace.clone()] } else { Vec::new() };
    LearnResult {
        best: chain.tracker.entries().to_vec(),
        stats: chain.stats.clone(),
        traces,
        sampling_secs: timer.elapsed_secs(),
        chains: 1,
    }
}

/// Run `chains` independent chains in parallel, each built from
/// `make_scorer(chain_id)` on its own thread, and merge the trackers.
///
/// The factory runs *on the worker thread*, so non-`Send` engines (e.g.
/// an engine holding PJRT handles) can still be used with `chains = 1`;
/// for >1 chains the factory itself must be `Sync`.
pub fn run_chains_parallel<F, S>(
    make_scorer: F,
    n: usize,
    iters: u64,
    topk: usize,
    seed: u64,
    chains: usize,
) -> LearnResult
where
    F: Fn(usize) -> S + Sync,
    S: OrderScorer,
{
    run_chains_parallel_traced(make_scorer, n, iters, topk, seed, chains, false)
}

/// [`run_chains_parallel`] with optional trace recording: each chain's
/// per-iteration score trace is returned in [`LearnResult::traces`]
/// (chain order), feeding the multi-chain convergence diagnostics.
pub fn run_chains_parallel_traced<F, S>(
    make_scorer: F,
    n: usize,
    iters: u64,
    topk: usize,
    seed: u64,
    chains: usize,
    record_trace: bool,
) -> LearnResult
where
    F: Fn(usize) -> S + Sync,
    S: OrderScorer,
{
    let mut spec = ChainSpec::new(n, iters, topk, seed);
    spec.chains = chains;
    spec.record_trace = record_trace;
    run_chains_parallel_spec(make_scorer, &spec)
}

/// Run `spec.chains` independent chains in parallel as described by
/// `spec`, merging trackers/stats/traces after join.
pub fn run_chains_parallel_spec<F, S>(make_scorer: F, spec: &ChainSpec) -> LearnResult
where
    F: Fn(usize) -> S + Sync,
    S: OrderScorer,
{
    assert!(spec.chains >= 1);
    let timer = Timer::start();
    let results: Vec<(BestGraphTracker, ChainStats)> = std::thread::scope(|scope| {
        let make_scorer = &make_scorer;
        let handles: Vec<_> = (0..spec.chains)
            .map(|c| {
                scope.spawn(move || {
                    let mut scorer = make_scorer(c);
                    let mut chain = McmcChain::new(
                        &mut scorer,
                        spec.n,
                        spec.topk,
                        spec.seed.wrapping_add(c as u64 * 0x9E37),
                    );
                    chain.set_proposal(spec.proposal);
                    chain.set_record_trace(spec.record_trace);
                    if let Some(control) = &spec.control {
                        chain.set_control_indexed(control.clone(), c);
                    }
                    chain.run(spec.iters);
                    (chain.tracker.clone(), chain.stats.clone())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("chain panicked")).collect()
    });

    let mut merged = BestGraphTracker::new(spec.topk);
    let mut stats = ChainStats::default();
    let mut traces = Vec::new();
    for (tracker, s) in &results {
        merged.merge(tracker);
        stats.iterations += s.iterations;
        stats.accepted += s.accepted;
        if spec.record_trace {
            traces.push(s.trace.clone());
        }
    }
    LearnResult {
        best: merged.entries().to_vec(),
        stats,
        traces,
        sampling_secs: timer.elapsed_secs(),
        chains: spec.chains,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scorer::testutil::fixture;
    use crate::scorer::SerialScorer;

    #[test]
    fn single_chain_returns_graphs() {
        let (_, table) = fixture(7, 3, 200, 121);
        let mut scorer = SerialScorer::new(&table);
        let res = run_chain(&mut scorer, 7, 200, 3, 122);
        assert!(!res.best.is_empty());
        assert!(res.best_score().unwrap().is_finite());
        assert!(res.sampling_secs > 0.0);
        assert!(res.traces.is_empty());
        // entries sorted descending
        for w in res.best.windows(2) {
            assert!(w[0].0 >= w[1].0);
        }
    }

    #[test]
    fn parallel_chains_at_least_match_single() {
        let (_, table) = fixture(7, 3, 200, 123);
        let single = {
            let mut scorer = SerialScorer::new(&table);
            run_chain(&mut scorer, 7, 300, 1, 42)
        };
        let multi = run_chains_parallel(|_| SerialScorer::new(&table), 7, 300, 1, 42, 4);
        // 4 chains including the same seed as the single run ⇒ can't do worse
        assert!(multi.best_score().unwrap() >= single.best_score().unwrap() - 1e-9);
        assert_eq!(multi.stats.iterations, 4 * 300);
        assert_eq!(multi.chains, 4);
    }

    #[test]
    fn parallel_is_deterministic() {
        let (_, table) = fixture(6, 2, 150, 124);
        let a = run_chains_parallel(|_| SerialScorer::new(&table), 6, 100, 2, 7, 3);
        let b = run_chains_parallel(|_| SerialScorer::new(&table), 6, 100, 2, 7, 3);
        assert_eq!(a.best_score(), b.best_score());
        assert_eq!(a.stats.accepted, b.stats.accepted);
    }

    #[test]
    fn traced_runs_return_per_chain_traces() {
        let (_, table) = fixture(6, 2, 150, 125);
        let res = run_chains_parallel_traced(|_| SerialScorer::new(&table), 6, 80, 1, 9, 3, true);
        assert_eq!(res.traces.len(), 3);
        assert!(res.traces.iter().all(|t| t.len() == 80));
        assert!(res.traces.iter().flatten().all(|s| s.is_finite()));
        // untraced leaves traces empty
        let res = run_chains_parallel(|_| SerialScorer::new(&table), 6, 80, 1, 9, 2);
        assert!(res.traces.is_empty());
    }

    #[test]
    fn spec_runner_drives_proposal_kinds_deterministically() {
        use super::super::chain::ProposalKind;
        let (_, table) = fixture(7, 3, 150, 128);
        for proposal in [ProposalKind::Swap, ProposalKind::Adjacent, ProposalKind::Mixed] {
            let mut spec = ChainSpec::new(7, 120, 2, 129);
            spec.chains = 2;
            spec.proposal = proposal;
            let a = run_chains_parallel_spec(|_| SerialScorer::new(&table), &spec);
            let b = run_chains_parallel_spec(|_| SerialScorer::new(&table), &spec);
            assert_eq!(a.best_score(), b.best_score(), "{proposal:?}");
            assert_eq!(a.stats.accepted, b.stats.accepted, "{proposal:?}");
            assert_eq!(a.stats.iterations, 240, "{proposal:?}");
        }
        // the swap spec reproduces the classic entry point exactly
        let spec = ChainSpec::new(7, 120, 2, 129);
        let mut scorer = SerialScorer::new(&table);
        let via_spec = run_chain_spec(&mut scorer, &spec);
        let mut scorer = SerialScorer::new(&table);
        let classic = run_chain(&mut scorer, 7, 120, 2, 129);
        assert_eq!(via_spec.best_score(), classic.best_score());
        assert_eq!(via_spec.stats.accepted, classic.stats.accepted);
    }

    #[test]
    fn zero_iteration_single_chain_still_tracks_initial_order() {
        // `McmcChain::new` offers the starting order's best graph, so
        // even a 0-iteration run has a graph; the Option API is for
        // degenerate constructions (e.g. empty merges), not this.
        let (_, table) = fixture(5, 2, 100, 126);
        let mut scorer = SerialScorer::new(&table);
        let res = run_chain(&mut scorer, 5, 0, 1, 127);
        assert!(res.best_dag().is_some());
        let empty = LearnResult {
            best: Vec::new(),
            stats: ChainStats::default(),
            traces: Vec::new(),
            sampling_secs: 0.0,
            chains: 0,
        };
        assert!(empty.best_dag().is_none());
        assert!(empty.best_score().is_none());
    }
}
