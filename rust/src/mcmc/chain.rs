//! One Metropolis–Hastings chain over the order space (Algorithm 1).
//!
//! Each step: propose a swap of two positions (see [`ProposalKind`]),
//! score the proposed order through the engine's incremental
//! propose/commit/rollback protocol (`OrderScorer::propose_swap` — a
//! full rescore for engines that don't opt in), accept with probability
//! `min(1, P(≺_new)/P(≺))` — in log10 score terms,
//! `ln(u) < (score_new − score_old) · ln(10)` — and, per the paper, offer
//! the accepted order's best graph to the tracker. All proposal kinds
//! are symmetric moves, so no Hastings correction is needed.

use std::sync::Arc;

use super::best::BestGraphTracker;
use super::control::{ChainControl, ScoreWindow};
use super::order::Order;
use crate::scorer::{BestGraph, OrderScorer};
use crate::util::Pcg32;

/// How [`McmcChain::step`] proposes the next order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProposalKind {
    /// Swap two uniformly random distinct positions (the paper's move;
    /// expected rescore interval ~ n/3 for incremental engines).
    Swap,
    /// Swap two adjacent positions — interval length 2, the O(1) regime
    /// for incremental engines (local mixing only).
    Adjacent,
    /// Fair per-step mix: adjacent transpositions for cheap local moves,
    /// uniform swaps for long jumps.
    Mixed,
}

impl ProposalKind {
    /// Parse from CLI text (`--proposal swap|adjacent|mixed`).
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        Ok(match text {
            "swap" | "uniform" => ProposalKind::Swap,
            "adjacent" | "adj" => ProposalKind::Adjacent,
            "mixed" | "mix" => ProposalKind::Mixed,
            other => anyhow::bail!("unknown proposal {other:?} (swap|adjacent|mixed)"),
        })
    }

    /// Proposal name for logs and checkpoint fingerprints.
    pub fn name(&self) -> &'static str {
        match self {
            ProposalKind::Swap => "swap",
            ProposalKind::Adjacent => "adjacent",
            ProposalKind::Mixed => "mixed",
        }
    }
}

/// Counters exposed for logging / convergence diagnostics.
#[derive(Debug, Clone, Default)]
pub struct ChainStats {
    pub iterations: u64,
    pub accepted: u64,
    /// Scores of each iteration's *current* order (for trace plots);
    /// recorded only when `record_trace` is on.
    pub trace: Vec<f64>,
}

impl ChainStats {
    /// Fraction of proposals accepted.
    pub fn accept_rate(&self) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.accepted as f64 / self.iterations as f64
        }
    }
}

/// A running MH chain bound to a scoring engine.
pub struct McmcChain<'s, S: OrderScorer + ?Sized> {
    scorer: &'s mut S,
    order: Order,
    current_score: f64,
    out: BestGraph,
    pub tracker: BestGraphTracker,
    pub stats: ChainStats,
    record_trace: bool,
    proposal: ProposalKind,
    control: Option<Arc<ChainControl>>,
    window: Option<Arc<ScoreWindow>>,
    rng: Pcg32,
}

impl<'s, S: OrderScorer + ?Sized> McmcChain<'s, S> {
    /// Start a chain from a random order.
    pub fn new(scorer: &'s mut S, n: usize, topk: usize, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed);
        let order = Order::random(n, &mut rng);
        let mut out = BestGraph::new(n);
        let current_score = scorer.score_order(&order, &mut out);
        let mut tracker = BestGraphTracker::new(topk);
        tracker.offer(out.total(), &out.to_dag());
        McmcChain {
            scorer,
            order,
            current_score,
            out,
            tracker,
            stats: ChainStats::default(),
            record_trace: false,
            proposal: ProposalKind::Swap,
            control: None,
            window: None,
            rng,
        }
    }

    /// Rebuild a chain mid-stream from checkpointed parts: the current
    /// order, its score, the RNG, and the tracker/stats accumulated so
    /// far. The next [`Self::step`] continues the original trajectory
    /// bit-for-bit (given the same deterministic scorer).
    pub fn resume(
        scorer: &'s mut S,
        order: Order,
        current_score: f64,
        rng: Pcg32,
        tracker: BestGraphTracker,
        stats: ChainStats,
    ) -> Self {
        let n = order.n();
        McmcChain {
            scorer,
            order,
            current_score,
            out: BestGraph::new(n),
            tracker,
            stats,
            record_trace: false,
            proposal: ProposalKind::Swap,
            control: None,
            window: None,
            rng,
        }
    }

    /// Tear the chain down into its resumable parts:
    /// `(order, current_score, rng, tracker, stats)`.
    pub fn into_parts(self) -> (Order, f64, Pcg32, BestGraphTracker, ChainStats) {
        (self.order, self.current_score, self.rng, self.tracker, self.stats)
    }

    /// Record a per-iteration score trace (costs one f64 per step).
    pub fn set_record_trace(&mut self, on: bool) {
        self.record_trace = on;
    }

    /// Select the proposal move (default [`ProposalKind::Swap`]). Set
    /// before running — switching mid-chain changes the RNG consumption
    /// pattern and thus the trajectory.
    pub fn set_proposal(&mut self, proposal: ProposalKind) {
        self.proposal = proposal;
    }

    /// Attach a shared [`ChainControl`] as chain 0 of its run — see
    /// [`Self::set_control_indexed`].
    pub fn set_control(&mut self, control: Arc<ChainControl>) {
        self.set_control_indexed(control, 0);
    }

    /// Attach a shared [`ChainControl`] as chain `index` of its run:
    /// [`Self::run`] / [`Self::run_observed`] poll its cancel flag
    /// between steps, fold every completed step into its progress
    /// counters, record post-step scores into the control's rolling
    /// score window for `index` (feeding live PSRF/ESS gauges), and
    /// tick the global `bnlearn_chain_*` telemetry. The control never
    /// touches RNG or scoring state, so an uncancelled controlled run
    /// is bit-identical to an uncontrolled one. Keyed by `index` so a
    /// checkpoint-segmented chain keeps appending to the same window
    /// across segments.
    pub fn set_control_indexed(&mut self, control: Arc<ChainControl>, index: usize) {
        self.window = Some(control.window(index));
        self.control = Some(control);
    }

    /// True when an attached control has been cancelled (always false
    /// without one).
    pub fn is_cancelled(&self) -> bool {
        self.control.as_ref().is_some_and(|c| c.is_cancelled())
    }

    /// The current order.
    pub fn order(&self) -> &Order {
        &self.order
    }

    /// Score of the current order.
    pub fn current_score(&self) -> f64 {
        self.current_score
    }

    /// Draw the paper's move: two distinct uniformly random positions.
    fn draw_swap(&mut self, n: usize) -> (usize, usize) {
        let a = self.rng.gen_range(n);
        let mut b = self.rng.gen_range(n);
        while b == a && n > 1 {
            b = self.rng.gen_range(n);
        }
        (a, b)
    }

    /// Draw the next proposal's positions per the configured kind.
    fn propose_positions(&mut self, n: usize) -> (usize, usize) {
        match self.proposal {
            ProposalKind::Swap => self.draw_swap(n),
            ProposalKind::Adjacent if n < 2 => (0, 0),
            ProposalKind::Adjacent => {
                let a = self.rng.gen_range(n - 1);
                (a, a + 1)
            }
            ProposalKind::Mixed if n < 2 => (0, 0),
            ProposalKind::Mixed => {
                if self.rng.gen_range(2) == 0 {
                    let a = self.rng.gen_range(n - 1);
                    (a, a + 1)
                } else {
                    self.draw_swap(n)
                }
            }
        }
    }

    /// One MH step; returns `true` if the proposal was accepted.
    ///
    /// Drives the engine's propose/commit/rollback protocol: the scorer
    /// sees the already-swapped order plus the swapped interval, so
    /// incremental engines rescore only `a..=b`; default engines fall
    /// back to a full rescore and behave exactly as before.
    pub fn step(&mut self) -> bool {
        let n = self.order.n();
        self.stats.iterations += 1;
        // Propose: swap two positions (Section III-C).
        let (a, b) = self.propose_positions(n);
        self.order.swap_positions(a, b);
        let (lo, hi) = (a.min(b), a.max(b));
        let proposed = self.scorer.propose_swap(&self.order, lo, hi, &mut self.out);

        // Scores are log10; MH uses natural log on the uniform draw.
        let log_u = self.rng.gen_f64_open().ln();
        let accept = log_u < (proposed - self.current_score) * std::f64::consts::LN_10;
        if accept {
            self.current_score = proposed;
            self.stats.accepted += 1;
            self.scorer.commit_swap(&mut self.out);
            // Paper: on acceptance, compare the order's best graph with
            // the record.
            self.tracker.offer(self.out.total(), &self.out.to_dag());
        } else {
            self.scorer.rollback_swap();
            self.order.swap_positions(a, b); // undo
        }
        if self.record_trace {
            self.stats.trace.push(self.current_score);
        }
        if let Some(control) = &self.control {
            // Telemetry only — write-only from the chain's point of
            // view, gated on an attached control so bare library/bench
            // chains pay zero per-step atomics.
            control.count_step(accept);
            let tm = crate::telemetry::metrics::chain();
            tm.steps.inc();
            if accept {
                tm.accepts.inc();
            }
            tm.interval_length.observe((hi - lo) as f64);
            if let Some(window) = &self.window {
                window.record(self.current_score);
            }
        }
        accept
    }

    /// Run `iters` steps, stopping early between steps if an attached
    /// [`ChainControl`] is cancelled.
    pub fn run(&mut self, iters: u64) {
        for _ in 0..iters {
            if self.is_cancelled() {
                break;
            }
            self.step();
        }
    }

    /// Run `iters` steps, handing the post-step state (current order +
    /// its score) to `observe` after every transition — the sample
    /// emission hook the posterior layer accumulates edge marginals
    /// through. Rejected proposals re-emit the unchanged state, as MCMC
    /// averaging requires. Cancellation stops between steps, after the
    /// last completed step's emission.
    pub fn run_observed<F: FnMut(&Order, f64)>(&mut self, iters: u64, mut observe: F) {
        for _ in 0..iters {
            if self.is_cancelled() {
                break;
            }
            self.step();
            observe(&self.order, self.current_score);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scorer::testutil::fixture;
    use crate::scorer::SerialScorer;

    #[test]
    fn chain_improves_score() {
        let (_, table) = fixture(8, 3, 300, 111);
        let mut scorer = SerialScorer::new(&table);
        let mut chain = McmcChain::new(&mut scorer, 8, 3, 112);
        let initial = chain.current_score();
        chain.run(300);
        let best = chain.tracker.best().unwrap().0;
        assert!(best >= initial, "best {best} < initial {initial}");
        assert!(chain.stats.accept_rate() > 0.0);
    }

    #[test]
    fn tracker_scores_match_graph_rescoring() {
        let (_, table) = fixture(6, 2, 150, 113);
        let mut scorer = SerialScorer::new(&table);
        let mut chain = McmcChain::new(&mut scorer, 6, 2, 114);
        chain.run(100);
        for (score, dag) in chain.tracker.entries().iter() {
            // Rescore the graph directly from the table.
            let direct: f64 = (0..6)
                .map(|i| table.score_of(i, dag.parents(i)) as f64)
                .sum();
            assert!((score - direct).abs() < 1e-4, "{score} vs {direct}");
        }
    }

    #[test]
    fn trace_recording() {
        let (_, table) = fixture(5, 2, 100, 115);
        let mut scorer = SerialScorer::new(&table);
        let mut chain = McmcChain::new(&mut scorer, 5, 1, 116);
        chain.set_record_trace(true);
        chain.run(50);
        assert_eq!(chain.stats.trace.len(), 50);
        // trace is the running current score — never NaN
        assert!(chain.stats.trace.iter().all(|s| s.is_finite()));
    }

    #[test]
    fn deterministic_given_seed() {
        let (_, table) = fixture(6, 2, 120, 117);
        let mut s1 = SerialScorer::new(&table);
        let mut c1 = McmcChain::new(&mut s1, 6, 2, 42);
        c1.run(200);
        let mut s2 = SerialScorer::new(&table);
        let mut c2 = McmcChain::new(&mut s2, 6, 2, 42);
        c2.run(200);
        assert_eq!(c1.current_score(), c2.current_score());
        assert_eq!(c1.stats.accepted, c2.stats.accepted);
    }

    #[test]
    fn resume_continues_trajectory_bit_for_bit() {
        let (_, table) = fixture(7, 2, 150, 120);
        // Uninterrupted 200-step chain.
        let mut s1 = SerialScorer::new(&table);
        let mut full = McmcChain::new(&mut s1, 7, 2, 55);
        full.set_record_trace(true);
        full.run(200);

        // Same chain, split 80 + 120 through into_parts/resume.
        let mut s2 = SerialScorer::new(&table);
        let mut head = McmcChain::new(&mut s2, 7, 2, 55);
        head.set_record_trace(true);
        head.run(80);
        let (order, score, rng, tracker, stats) = head.into_parts();
        let mut s3 = SerialScorer::new(&table);
        let mut tail = McmcChain::resume(&mut s3, order, score, rng, tracker, stats);
        tail.set_record_trace(true);
        tail.run(120);

        assert_eq!(full.current_score(), tail.current_score());
        assert_eq!(full.order(), tail.order());
        assert_eq!(full.stats.accepted, tail.stats.accepted);
        assert_eq!(full.stats.trace, tail.stats.trace);
        assert_eq!(full.tracker.entries(), tail.tracker.entries());
    }

    #[test]
    fn run_observed_emits_every_iteration() {
        let (_, table) = fixture(6, 2, 120, 121);
        let mut scorer = SerialScorer::new(&table);
        let mut chain = McmcChain::new(&mut scorer, 6, 1, 122);
        let mut emitted = Vec::new();
        chain.run_observed(40, |order, score| {
            assert!(order.check());
            emitted.push(score);
        });
        assert_eq!(emitted.len(), 40);
        assert_eq!(*emitted.last().unwrap(), chain.current_score());
    }

    #[test]
    fn single_node_chain_is_stable() {
        let (_, table) = fixture(1, 0, 50, 118);
        let mut scorer = SerialScorer::new(&table);
        let mut chain = McmcChain::new(&mut scorer, 1, 1, 119);
        chain.run(10);
        assert!(chain.current_score().is_finite());
    }

    /// The delta engine must reproduce the full-rescore chain exactly:
    /// same accepts, same trace, same tracker entries.
    #[test]
    fn delta_chain_is_bit_for_bit_identical_to_full_chain() {
        use crate::scorer::DeltaScorer;
        let (_, table) = fixture(8, 3, 250, 130);
        for proposal in [ProposalKind::Swap, ProposalKind::Adjacent, ProposalKind::Mixed] {
            let mut full = SerialScorer::new(&table);
            let mut c_full = McmcChain::new(&mut full, 8, 3, 131);
            c_full.set_proposal(proposal);
            c_full.set_record_trace(true);
            c_full.run(300);

            let mut delta = DeltaScorer::new(SerialScorer::new(&table));
            let mut c_delta = McmcChain::new(&mut delta, 8, 3, 131);
            c_delta.set_proposal(proposal);
            c_delta.set_record_trace(true);
            c_delta.run(300);

            assert_eq!(c_full.current_score(), c_delta.current_score(), "{proposal:?}");
            assert_eq!(c_full.order(), c_delta.order(), "{proposal:?}");
            assert_eq!(c_full.stats.accepted, c_delta.stats.accepted, "{proposal:?}");
            assert_eq!(c_full.stats.trace, c_delta.stats.trace, "{proposal:?}");
            assert_eq!(c_full.tracker.entries(), c_delta.tracker.entries(), "{proposal:?}");
        }
    }

    /// Adjacent and mixed proposals keep every chain invariant: the
    /// current score always equals a from-scratch rescore of the order.
    #[test]
    fn non_uniform_proposals_preserve_score_invariant() {
        let (_, table) = fixture(7, 3, 200, 132);
        for proposal in [ProposalKind::Adjacent, ProposalKind::Mixed] {
            let mut scorer = SerialScorer::new(&table);
            let mut chain = McmcChain::new(&mut scorer, 7, 2, 133);
            chain.set_proposal(proposal);
            chain.run(150);
            let order = chain.order().clone();
            let score = chain.current_score();
            let mut check = SerialScorer::new(&table);
            let mut out = BestGraph::new(7);
            assert!((score - check.score_order(&order, &mut out)).abs() < 1e-9, "{proposal:?}");
            assert!(chain.stats.accept_rate() > 0.0, "{proposal:?}");
        }
    }

    /// A pre-cancelled control stops the chain before its first step; a
    /// live one ticks the shared counters without touching the
    /// trajectory.
    #[test]
    fn control_cancels_between_steps_and_counts_progress() {
        let (_, table) = fixture(6, 2, 120, 140);
        let control = ChainControl::shared();
        let mut scorer = SerialScorer::new(&table);
        let mut chain = McmcChain::new(&mut scorer, 6, 2, 141);
        chain.set_control(control.clone());
        chain.run(50);
        assert_eq!(chain.stats.iterations, 50);
        assert_eq!(control.progress(), (50, chain.stats.accepted));

        control.cancel();
        chain.run(100);
        assert_eq!(chain.stats.iterations, 50, "cancelled chain takes no further steps");
        let mut observed = 0;
        chain.run_observed(100, |_, _| observed += 1);
        assert_eq!(observed, 0);

        // An uncancelled controlled chain is bit-identical to a plain one.
        let mut s1 = SerialScorer::new(&table);
        let mut plain = McmcChain::new(&mut s1, 6, 2, 141);
        plain.run(50);
        assert_eq!(plain.current_score(), chain.current_score());
        assert_eq!(plain.order(), chain.order());
        assert_eq!(plain.stats.accepted, chain.stats.accepted);
    }

    #[test]
    fn proposal_kind_parse_and_name() {
        assert_eq!(ProposalKind::parse("swap").unwrap(), ProposalKind::Swap);
        assert_eq!(ProposalKind::parse("uniform").unwrap(), ProposalKind::Swap);
        assert_eq!(ProposalKind::parse("adjacent").unwrap(), ProposalKind::Adjacent);
        assert_eq!(ProposalKind::parse("mix").unwrap(), ProposalKind::Mixed);
        assert!(ProposalKind::parse("teleport").is_err());
        assert_eq!(ProposalKind::Adjacent.name(), "adjacent");
    }
}
