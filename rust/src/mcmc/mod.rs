//! The MCMC order sampler (Section III / Algorithm 1): Metropolis–Hastings
//! random walk over topological orders, driving a pluggable order-scoring
//! engine, with best-graph tracking.

pub mod best;
pub mod chain;
pub mod control;
pub mod graphspace;
pub mod order;
pub mod runner;

pub use best::BestGraphTracker;
pub use chain::{ChainStats, McmcChain, ProposalKind};
pub use control::ChainControl;
pub use graphspace::GraphChain;
pub use order::Order;
pub use runner::{
    run_chain, run_chain_spec, run_chain_traced, run_chains_parallel, run_chains_parallel_spec,
    run_chains_parallel_traced, ChainSpec, LearnResult,
};
