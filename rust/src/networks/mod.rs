//! Repository of published benchmark network *structures*.
//!
//! The paper evaluates on the 11-node human T-cell signaling transduction
//! network (Sachs et al. 2005) and the 37-node ALARM network (Beinlich et
//! al. 1989, via the Bayesian network repository). We encode the published
//! structures; CPTs are synthesized with peaked random rows
//! (DESIGN.md §7 — the paper only consumes the *data*, which we generate
//! by forward-sampling the true structure).

pub mod alarm;
pub mod asia;
pub mod child;
pub mod sachs;
pub mod tiled;

use crate::bn::{Dag, Network};
use crate::util::Pcg32;

/// A named structure with per-node arities.
pub struct NamedStructure {
    pub name: &'static str,
    pub node_names: Vec<&'static str>,
    pub dag: Dag,
    pub states: Vec<usize>,
}

impl NamedStructure {
    /// Attach synthesized CPTs (seeded) to get a sampling-ready network.
    pub fn with_cpts(&self, seed: u64) -> Network {
        let mut rng = Pcg32::new(seed);
        let mut net =
            Network::with_random_cpts(self.dag.clone(), self.states.clone(), &mut rng);
        net.names = self.node_names.iter().map(|s| s.to_string()).collect();
        net
    }
}

/// Look a repository network up by name.
pub fn by_name(name: &str) -> Option<NamedStructure> {
    match name {
        "alarm" => Some(alarm::alarm()),
        "sachs" | "stn" => Some(sachs::sachs()),
        "asia" => Some(asia::asia()),
        "child" => Some(child::child()),
        "tiled64" => Some(tiled::tiled64()),
        "tiled128" => Some(tiled::tiled128()),
        "tiled256" => Some(tiled::tiled256()),
        _ => None,
    }
}

/// All repository network names.
pub fn names() -> &'static [&'static str] {
    &["alarm", "sachs", "asia", "child", "tiled64", "tiled128", "tiled256"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert!(by_name("alarm").is_some());
        assert!(by_name("sachs").is_some());
        assert!(by_name("stn").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn all_networks_are_valid() {
        for name in names() {
            let s = by_name(name).unwrap();
            assert!(s.dag.is_acyclic(), "{name} has a cycle");
            assert_eq!(s.node_names.len(), s.dag.n(), "{name} name count");
            assert_eq!(s.states.len(), s.dag.n(), "{name} arity count");
            let net = s.with_cpts(7);
            assert!(net.validate().is_ok(), "{name} CPTs invalid");
        }
    }

    #[test]
    fn cpts_deterministic_by_seed() {
        let s = by_name("asia").unwrap();
        let a = s.with_cpts(3);
        let b = s.with_cpts(3);
        assert_eq!(a.cpts[1].probs, b.cpts[1].probs);
    }
}
