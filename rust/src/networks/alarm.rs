//! The ALARM monitoring network (Beinlich et al. 1989): 37 nodes,
//! 46 edges, maximal in-degree 4 — the large real network of the paper's
//! Table IV. Structure and arities follow the Bayesian network repository.

use super::NamedStructure;
use crate::bn::Dag;

// Node indices (alphabetical-free: repository order).
const NODES: [(&str, usize); 37] = [
    ("CVP", 3),            // 0
    ("PCWP", 3),           // 1
    ("HISTORY", 2),        // 2
    ("TPR", 3),            // 3
    ("BP", 3),             // 4
    ("CO", 3),             // 5
    ("HRBP", 3),           // 6
    ("HREKG", 3),          // 7
    ("HRSAT", 3),          // 8
    ("PAP", 3),            // 9
    ("SAO2", 3),           // 10
    ("FIO2", 2),           // 11
    ("PRESS", 4),          // 12
    ("EXPCO2", 4),         // 13
    ("MINVOL", 4),         // 14
    ("MINVOLSET", 3),      // 15
    ("HYPOVOLEMIA", 2),    // 16
    ("LVFAILURE", 2),      // 17
    ("ANAPHYLAXIS", 2),    // 18
    ("INSUFFANESTH", 2),   // 19
    ("PULMEMBOLUS", 2),    // 20
    ("INTUBATION", 3),     // 21
    ("KINKEDTUBE", 2),     // 22
    ("DISCONNECT", 2),     // 23
    ("LVEDVOLUME", 3),     // 24
    ("STROKEVOLUME", 3),   // 25
    ("CATECHOL", 2),       // 26
    ("ERRLOWOUTPUT", 2),   // 27
    ("HR", 3),             // 28
    ("ERRCAUTER", 2),      // 29
    ("SHUNT", 2),          // 30
    ("PVSAT", 3),          // 31
    ("ARTCO2", 3),         // 32
    ("VENTALV", 4),        // 33
    ("VENTLUNG", 4),       // 34
    ("VENTTUBE", 4),       // 35
    ("VENTMACH", 4),       // 36
];

/// The 46 published arcs as `(from, to)` index pairs.
const EDGES: [(usize, usize); 46] = [
    (24, 0),  // LVEDVOLUME -> CVP
    (24, 1),  // LVEDVOLUME -> PCWP
    (17, 2),  // LVFAILURE -> HISTORY
    (18, 3),  // ANAPHYLAXIS -> TPR
    (5, 4),   // CO -> BP
    (3, 4),   // TPR -> BP
    (28, 5),  // HR -> CO
    (25, 5),  // STROKEVOLUME -> CO
    (27, 6),  // ERRLOWOUTPUT -> HRBP
    (28, 6),  // HR -> HRBP
    (29, 7),  // ERRCAUTER -> HREKG
    (28, 7),  // HR -> HREKG
    (29, 8),  // ERRCAUTER -> HRSAT
    (28, 8),  // HR -> HRSAT
    (20, 9),  // PULMEMBOLUS -> PAP
    (31, 10), // PVSAT -> SAO2
    (30, 10), // SHUNT -> SAO2
    (21, 12), // INTUBATION -> PRESS
    (22, 12), // KINKEDTUBE -> PRESS
    (35, 12), // VENTTUBE -> PRESS
    (32, 13), // ARTCO2 -> EXPCO2
    (34, 13), // VENTLUNG -> EXPCO2
    (21, 14), // INTUBATION -> MINVOL
    (34, 14), // VENTLUNG -> MINVOL
    (16, 24), // HYPOVOLEMIA -> LVEDVOLUME
    (17, 24), // LVFAILURE -> LVEDVOLUME
    (16, 25), // HYPOVOLEMIA -> STROKEVOLUME
    (17, 25), // LVFAILURE -> STROKEVOLUME
    (32, 26), // ARTCO2 -> CATECHOL
    (19, 26), // INSUFFANESTH -> CATECHOL
    (10, 26), // SAO2 -> CATECHOL
    (3, 26),  // TPR -> CATECHOL
    (26, 28), // CATECHOL -> HR
    (21, 30), // INTUBATION -> SHUNT
    (20, 30), // PULMEMBOLUS -> SHUNT
    (11, 31), // FIO2 -> PVSAT
    (33, 31), // VENTALV -> PVSAT
    (33, 32), // VENTALV -> ARTCO2
    (21, 33), // INTUBATION -> VENTALV
    (34, 33), // VENTLUNG -> VENTALV
    (21, 34), // INTUBATION -> VENTLUNG
    (22, 34), // KINKEDTUBE -> VENTLUNG
    (35, 34), // VENTTUBE -> VENTLUNG
    (23, 35), // DISCONNECT -> VENTTUBE
    (36, 35), // VENTMACH -> VENTTUBE
    (15, 36), // MINVOLSET -> VENTMACH
];

/// The ALARM structure.
pub fn alarm() -> NamedStructure {
    NamedStructure {
        name: "alarm",
        node_names: NODES.iter().map(|&(n, _)| n).collect(),
        dag: Dag::from_edges(37, &EDGES),
        states: NODES.iter().map(|&(_, s)| s).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_literature() {
        let a = alarm();
        assert_eq!(a.dag.n(), 37);
        assert_eq!(a.dag.edge_count(), 46);
        assert!(a.dag.is_acyclic());
        assert_eq!(a.dag.max_in_degree(), 4); // CATECHOL
    }

    #[test]
    fn catechol_parents() {
        let a = alarm();
        // CATECHOL (26) <- {TPR(3), SAO2(10), INSUFFANESTH(19), ARTCO2(32)}
        assert_eq!(a.dag.parents(26), &[3, 10, 19, 32]);
    }

    #[test]
    fn roots_are_the_published_ones() {
        let a = alarm();
        let roots: Vec<&str> = (0..37)
            .filter(|&i| a.dag.parents(i).is_empty())
            .map(|i| a.node_names[i])
            .collect();
        assert_eq!(
            roots,
            vec![
                "FIO2", "MINVOLSET", "HYPOVOLEMIA", "LVFAILURE", "ANAPHYLAXIS",
                "INSUFFANESTH", "PULMEMBOLUS", "INTUBATION", "KINKEDTUBE",
                "DISCONNECT", "ERRLOWOUTPUT", "ERRCAUTER"
            ]
        );
    }
}
