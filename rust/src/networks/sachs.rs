//! The 11-node human T-cell signaling transduction network (STN) of
//! Sachs et al., *Science* 2005 — the paper's small real network
//! (Table IV). 17 arcs over protein/phospholipid measurements,
//! discretized to 3 states (low / medium / high) as in the original
//! study and in the paper's gene-expression model.

use super::NamedStructure;
use crate::bn::Dag;

const NODES: [&str; 11] = [
    "Raf",  // 0
    "Mek",  // 1
    "Plcg", // 2
    "PIP2", // 3
    "PIP3", // 4
    "Erk",  // 5
    "Akt",  // 6
    "PKA",  // 7
    "PKC",  // 8
    "P38",  // 9
    "Jnk",  // 10
];

/// The 17 consensus arcs.
const EDGES: [(usize, usize); 17] = [
    (8, 0),  // PKC -> Raf
    (7, 0),  // PKA -> Raf
    (0, 1),  // Raf -> Mek
    (8, 1),  // PKC -> Mek
    (7, 1),  // PKA -> Mek
    (2, 3),  // Plcg -> PIP2
    (4, 3),  // PIP3 -> PIP2
    (2, 4),  // Plcg -> PIP3
    (1, 5),  // Mek -> Erk
    (7, 5),  // PKA -> Erk
    (5, 6),  // Erk -> Akt
    (7, 6),  // PKA -> Akt
    (8, 7),  // PKC -> PKA
    (7, 9),  // PKA -> P38
    (8, 9),  // PKC -> P38
    (7, 10), // PKA -> Jnk
    (8, 10), // PKC -> Jnk
];

/// The Sachs STN structure (3 states per node).
pub fn sachs() -> NamedStructure {
    NamedStructure {
        name: "sachs",
        node_names: NODES.to_vec(),
        dag: Dag::from_edges(11, &EDGES),
        states: vec![3; 11],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_literature() {
        let s = sachs();
        assert_eq!(s.dag.n(), 11);
        assert_eq!(s.dag.edge_count(), 17);
        assert!(s.dag.is_acyclic());
        assert!(s.dag.max_in_degree() <= 4);
    }

    #[test]
    fn pkc_is_a_root_driving_pka() {
        let s = sachs();
        assert!(s.dag.parents(8).is_empty()); // PKC root
        assert!(s.dag.has_edge(8, 7)); // PKC -> PKA
        assert_eq!(s.dag.parents(1), &[0, 7, 8]); // Mek <- Raf, PKA, PKC
    }

    #[test]
    fn all_nodes_ternary() {
        assert!(sachs().states.iter().all(|&r| r == 3));
    }
}
