//! The 64-node tiled/layered synthetic benchmark — the named workload
//! behind the paper's "more than 60 nodes" scale claim.
//!
//! Published benchmark repositories stop at ALARM's 37 nodes in this
//! codebase, so the >60-node regime had no named, reproducible
//! structure to exercise. `tiled64` is a fixed 8×8 layered DAG in the
//! style of synthetic gene-network tilings: 8 layers of 8 nodes, each
//! non-input node drawing 1–3 parents from the previous layer, wiring
//! chosen once by a **fixed generator seed** that is part of the
//! structure's definition (change the seed, change the benchmark).
//! All nodes are 3-state — the paper's gene expression model
//! (under/normal/over-expressed). Max in-degree is 3, so `--s 3`
//! covers the true structure.

use super::NamedStructure;
use crate::bn::Dag;
use crate::util::Pcg32;

/// Layers × width of the tiled structure.
const LAYERS: usize = 8;
const WIDTH: usize = 8;

/// The fixed wiring seed — part of the published structure definition.
const TILED_SEED: u64 = 0x7E64_0001;

#[rustfmt::skip]
const NODES: [&str; 64] = [
    "t00", "t01", "t02", "t03", "t04", "t05", "t06", "t07",
    "t08", "t09", "t10", "t11", "t12", "t13", "t14", "t15",
    "t16", "t17", "t18", "t19", "t20", "t21", "t22", "t23",
    "t24", "t25", "t26", "t27", "t28", "t29", "t30", "t31",
    "t32", "t33", "t34", "t35", "t36", "t37", "t38", "t39",
    "t40", "t41", "t42", "t43", "t44", "t45", "t46", "t47",
    "t48", "t49", "t50", "t51", "t52", "t53", "t54", "t55",
    "t56", "t57", "t58", "t59", "t60", "t61", "t62", "t63",
];

/// Deterministic layered wiring: each node of layer `l ≥ 1` draws 1–3
/// distinct parents from layer `l − 1`.
fn tiled_edges() -> Vec<(usize, usize)> {
    let mut rng = Pcg32::new(TILED_SEED);
    let mut edges = Vec::new();
    for layer in 1..LAYERS {
        for w in 0..WIDTH {
            let to = layer * WIDTH + w;
            let parents = 1 + rng.gen_range(3); // 1, 2, or 3
            let mut cand: Vec<usize> = ((layer - 1) * WIDTH..layer * WIDTH).collect();
            for _ in 0..parents {
                let pick = rng.gen_range(cand.len());
                edges.push((cand.swap_remove(pick), to));
            }
        }
    }
    edges
}

/// The 64-node tiled benchmark structure (8 layers × 8 nodes, 3-state).
pub fn tiled64() -> NamedStructure {
    NamedStructure {
        name: "tiled64",
        node_names: NODES.to_vec(),
        dag: Dag::from_edges(LAYERS * WIDTH, &tiled_edges()),
        states: vec![3; LAYERS * WIDTH],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_is_fixed_and_layered() {
        let t = tiled64();
        assert_eq!(t.dag.n(), 64);
        assert!(t.dag.is_acyclic());
        assert!(t.dag.max_in_degree() <= 3);
        // first layer has no parents; every later node has 1..=3
        for w in 0..WIDTH {
            assert!(t.dag.parents(w).is_empty());
        }
        for v in WIDTH..64 {
            let ps = t.dag.parents(v);
            assert!((1..=3).contains(&ps.len()), "node {v}: {ps:?}");
            // parents come from the previous layer only
            let layer = v / WIDTH;
            assert!(ps.iter().all(|&p| p / WIDTH == layer - 1), "node {v}: {ps:?}");
        }
    }

    #[test]
    fn wiring_is_deterministic() {
        // The fixed seed makes the structure a published artifact: two
        // builds agree edge for edge.
        let a = tiled64();
        let b = tiled64();
        assert_eq!(a.dag.edges(), b.dag.edges());
        assert!(a.dag.edge_count() >= 56, "at least one parent per non-input node");
    }
}
