//! The tiled/layered synthetic benchmark family — the named workloads
//! behind the paper's "more than 60 nodes" scale claim and this repo's
//! native-ragged 128/256-node runs.
//!
//! Published benchmark repositories stop at ALARM's 37 nodes in this
//! codebase, so the >60-node regime had no named, reproducible
//! structure to exercise. Each `tiledN` is a fixed layered DAG in the
//! style of synthetic gene-network tilings: `layers` layers of `width`
//! nodes, each non-input node drawing 1–3 parents from the previous
//! layer, wiring chosen once by a **fixed generator seed** that is part
//! of the structure's definition (change the seed, change the
//! benchmark). All nodes are 3-state — the paper's gene expression
//! model (under/normal/over-expressed). Max in-degree is 3, so `--s 3`
//! covers the true structure at every scale:
//!
//! * `tiled64` — 8 × 8, the original >60-node claim;
//! * `tiled128` — 16 × 8, the first native-ragged target past the old
//!   n = 64 key-space ceiling;
//! * `tiled256` — 32 × 8, the scale headroom benchmark.

use super::NamedStructure;
use crate::bn::Dag;
use crate::util::Pcg32;

/// Layers × width of the original 64-node tiling.
const LAYERS: usize = 8;
const WIDTH: usize = 8;

/// The fixed wiring seeds — part of the published structure
/// definitions (one per scale, so the 64-node prefix of `tiled128` is
/// NOT `tiled64`; each benchmark stands alone).
const TILED_SEED: u64 = 0x7E64_0001;
const TILED128_SEED: u64 = 0x7E64_0002;
const TILED256_SEED: u64 = 0x7E64_0003;

#[rustfmt::skip]
const NODES: [&str; 64] = [
    "t00", "t01", "t02", "t03", "t04", "t05", "t06", "t07",
    "t08", "t09", "t10", "t11", "t12", "t13", "t14", "t15",
    "t16", "t17", "t18", "t19", "t20", "t21", "t22", "t23",
    "t24", "t25", "t26", "t27", "t28", "t29", "t30", "t31",
    "t32", "t33", "t34", "t35", "t36", "t37", "t38", "t39",
    "t40", "t41", "t42", "t43", "t44", "t45", "t46", "t47",
    "t48", "t49", "t50", "t51", "t52", "t53", "t54", "t55",
    "t56", "t57", "t58", "t59", "t60", "t61", "t62", "t63",
];

/// Deterministic layered wiring: each node of layer `l ≥ 1` draws 1–3
/// distinct parents from layer `l − 1`.
fn tiled_edges(layers: usize, width: usize, seed: u64) -> Vec<(usize, usize)> {
    let mut rng = Pcg32::new(seed);
    let mut edges = Vec::new();
    for layer in 1..layers {
        for w in 0..width {
            let to = layer * width + w;
            let parents = 1 + rng.gen_range(3); // 1, 2, or 3
            let mut cand: Vec<usize> = ((layer - 1) * width..layer * width).collect();
            for _ in 0..parents {
                let pick = rng.gen_range(cand.len());
                edges.push((cand.swap_remove(pick), to));
            }
        }
    }
    edges
}

/// Generated `t000`-style node names for the >64-node tilings (leaked
/// once per call — structures are built a handful of times per run).
fn leaked_names(n: usize) -> Vec<&'static str> {
    (0..n).map(|i| &*Box::leak(format!("t{i:03}").into_boxed_str())).collect()
}

/// A layered tiling at an arbitrary scale.
fn tiled(
    name: &'static str,
    layers: usize,
    width: usize,
    seed: u64,
    node_names: Vec<&'static str>,
) -> NamedStructure {
    let n = layers * width;
    debug_assert_eq!(node_names.len(), n);
    NamedStructure {
        name,
        node_names,
        dag: Dag::from_edges(n, &tiled_edges(layers, width, seed)),
        states: vec![3; n],
    }
}

/// The 64-node tiled benchmark structure (8 layers × 8 nodes, 3-state).
pub fn tiled64() -> NamedStructure {
    tiled("tiled64", LAYERS, WIDTH, TILED_SEED, NODES.to_vec())
}

/// The 128-node tiled benchmark (16 layers × 8 nodes, 3-state) — the
/// first target past the old n = 64 key-space ceiling.
pub fn tiled128() -> NamedStructure {
    tiled("tiled128", 16, 8, TILED128_SEED, leaked_names(128))
}

/// The 256-node tiled benchmark (32 layers × 8 nodes, 3-state).
pub fn tiled256() -> NamedStructure {
    tiled("tiled256", 32, 8, TILED256_SEED, leaked_names(256))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_is_fixed_and_layered() {
        for (t, layers) in [(tiled64(), 8usize), (tiled128(), 16), (tiled256(), 32)] {
            let n = layers * WIDTH;
            assert_eq!(t.dag.n(), n, "{}", t.name);
            assert!(t.dag.is_acyclic());
            assert!(t.dag.max_in_degree() <= 3);
            // first layer has no parents; every later node has 1..=3
            for w in 0..WIDTH {
                assert!(t.dag.parents(w).is_empty());
            }
            for v in WIDTH..n {
                let ps = t.dag.parents(v);
                assert!((1..=3).contains(&ps.len()), "{} node {v}: {ps:?}", t.name);
                // parents come from the previous layer only
                let layer = v / WIDTH;
                assert!(
                    ps.iter().all(|&p| p / WIDTH == layer - 1),
                    "{} node {v}: {ps:?}",
                    t.name
                );
            }
        }
    }

    #[test]
    fn wiring_is_deterministic() {
        // The fixed seeds make the structures published artifacts: two
        // builds agree edge for edge, and the scales are distinct
        // benchmarks (not prefixes of one another).
        let a = tiled64();
        let b = tiled64();
        assert_eq!(a.dag.edges(), b.dag.edges());
        assert!(a.dag.edge_count() >= 56, "at least one parent per non-input node");
        assert_eq!(tiled128().dag.edges(), tiled128().dag.edges());
        assert_eq!(tiled256().dag.edges(), tiled256().dag.edges());
        let e64: std::collections::BTreeSet<(usize, usize)> =
            a.dag.edges().into_iter().collect();
        let prefix64: std::collections::BTreeSet<(usize, usize)> = tiled128()
            .dag
            .edges()
            .into_iter()
            .filter(|&(_, to)| to < 64)
            .collect();
        assert_ne!(e64, prefix64);
    }

    #[test]
    fn names_are_unique_and_sized() {
        for t in [tiled128(), tiled256()] {
            assert_eq!(t.node_names.len(), t.dag.n());
            let set: std::collections::BTreeSet<_> = t.node_names.iter().collect();
            assert_eq!(set.len(), t.dag.n(), "{} duplicate node names", t.name);
        }
    }
}
