//! The 20-node CHILD network (Spiegelhalter 1992, congenital heart
//! disease) — a published 20-node reference structure, the same size
//! class as the paper's synthetic ROC graphs.

use super::NamedStructure;
use crate::bn::Dag;

const NODES: [(&str, usize); 20] = [
    ("BirthAsphyxia", 2),   // 0
    ("Disease", 6),         // 1
    ("Age", 3),             // 2
    ("LVH", 2),             // 3
    ("DuctFlow", 3),        // 4
    ("CardiacMixing", 4),   // 5
    ("LungParench", 3),     // 6
    ("LungFlow", 3),        // 7
    ("Sick", 2),            // 8
    ("LVHreport", 2),       // 9
    ("HypDistrib", 2),      // 10
    ("HypoxiaInO2", 3),     // 11
    ("CO2", 3),             // 12
    ("ChestXray", 5),       // 13
    ("Grunting", 2),        // 14
    ("LowerBodyO2", 3),     // 15
    ("RUQO2", 3),           // 16
    ("CO2Report", 2),       // 17
    ("XrayReport", 5),      // 18
    ("GruntingReport", 2),  // 19
];

const EDGES: [(usize, usize); 25] = [
    (0, 1),   // BirthAsphyxia -> Disease
    (1, 2),   // Disease -> Age
    (8, 2),   // Sick -> Age
    (1, 3),   // Disease -> LVH
    (1, 4),   // Disease -> DuctFlow
    (1, 5),   // Disease -> CardiacMixing
    (1, 6),   // Disease -> LungParench
    (1, 7),   // Disease -> LungFlow
    (1, 8),   // Disease -> Sick
    (3, 9),   // LVH -> LVHreport
    (4, 10),  // DuctFlow -> HypDistrib
    (5, 10),  // CardiacMixing -> HypDistrib
    (5, 11),  // CardiacMixing -> HypoxiaInO2
    (6, 11),  // LungParench -> HypoxiaInO2
    (6, 12),  // LungParench -> CO2
    (6, 13),  // LungParench -> ChestXray
    (7, 13),  // LungFlow -> ChestXray
    (6, 14),  // LungParench -> Grunting
    (8, 14),  // Sick -> Grunting
    (10, 15), // HypDistrib -> LowerBodyO2
    (11, 15), // HypoxiaInO2 -> LowerBodyO2
    (11, 16), // HypoxiaInO2 -> RUQO2
    (12, 17), // CO2 -> CO2Report
    (13, 18), // ChestXray -> XrayReport
    (14, 19), // Grunting -> GruntingReport
];

/// The CHILD structure.
pub fn child() -> NamedStructure {
    NamedStructure {
        name: "child",
        node_names: NODES.iter().map(|&(n, _)| n).collect(),
        dag: Dag::from_edges(20, &EDGES),
        states: NODES.iter().map(|&(_, s)| s).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_literature() {
        let c = child();
        assert_eq!(c.dag.n(), 20);
        assert_eq!(c.dag.edge_count(), 25);
        assert!(c.dag.is_acyclic());
        assert!(c.dag.max_in_degree() <= 4);
    }

    #[test]
    fn disease_is_the_hub() {
        let c = child();
        let children = c.dag.edges().iter().filter(|&&(f, _)| f == 1).count();
        assert_eq!(children, 7);
    }
}
