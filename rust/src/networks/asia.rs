//! The 8-node ASIA ("chest clinic") network of Lauritzen & Spiegelhalter
//! 1988 — the classic small sanity-check network; handy for fast tests
//! and the quickstart example.

use super::NamedStructure;
use crate::bn::Dag;

const NODES: [&str; 8] = [
    "asia",   // 0 visit to Asia
    "tub",    // 1 tuberculosis
    "smoke",  // 2 smoking
    "lung",   // 3 lung cancer
    "bronc",  // 4 bronchitis
    "either", // 5 tub or lung
    "xray",   // 6 positive x-ray
    "dysp",   // 7 dyspnoea
];

const EDGES: [(usize, usize); 8] = [
    (0, 1), // asia -> tub
    (2, 3), // smoke -> lung
    (2, 4), // smoke -> bronc
    (1, 5), // tub -> either
    (3, 5), // lung -> either
    (5, 6), // either -> xray
    (5, 7), // either -> dysp
    (4, 7), // bronc -> dysp
];

/// The ASIA structure (all binary).
pub fn asia() -> NamedStructure {
    NamedStructure {
        name: "asia",
        node_names: NODES.to_vec(),
        dag: Dag::from_edges(8, &EDGES),
        states: vec![2; 8],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape() {
        let a = asia();
        assert_eq!(a.dag.n(), 8);
        assert_eq!(a.dag.edge_count(), 8);
        assert!(a.dag.is_acyclic());
        assert_eq!(a.dag.parents(7), &[4, 5]); // dysp <- bronc, either
    }
}
