//! # bnlearn
//!
//! Order-space MCMC Bayesian network structure learning with an
//! XLA/PJRT-accelerated scoring engine — a reproduction of Wang, Zhang,
//! Qian & Yuan, *"A Novel Learning Algorithm for Bayesian Network and Its
//! Efficient Implementation on GPU"* (2012).
//!
//! Layering (see DESIGN.md):
//! * substrates: [`util`], [`combinatorics`], [`bn`], [`data`], [`networks`]
//! * scoring: [`score`] (BDe local scores, preprocessing), [`priors`]
//! * the learner: [`mcmc`] (Metropolis–Hastings over orders) driving a
//!   pluggable [`scorer`] engine — serial ("GPP"), baselines, or the
//!   AOT-compiled XLA executable loaded by [`runtime`]
//! * evaluation: [`eval`] (ROC / SHD), experiment drivers in `examples/`
//!   and `benches/`, orchestrated through [`coordinator`].

pub mod bn;
pub mod combinatorics;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod mcmc;
pub mod networks;
pub mod priors;
pub mod runtime;
pub mod score;
pub mod scorer;
pub mod util;
