//! # bnlearn
//!
//! Order-space MCMC Bayesian network structure learning with an
//! XLA/PJRT-accelerated scoring engine — a reproduction of Wang, Zhang,
//! Qian & Yuan, *"A Novel Learning Algorithm for Bayesian Network and Its
//! Efficient Implementation on GPU"* (2012).
//!
//! Layering (see DESIGN.md at the repository root):
//! * substrates: [`util`], [`combinatorics`], [`bn`], [`data`], [`networks`],
//!   and the batched kernel execution layer [`exec`] (tiles over the
//!   `(node, parent-set)` space, static/balanced schedules — the CPU
//!   mirror of the paper's GPU task grid)
//! * scoring: [`score`] (BDe local scores, preprocessing, and the
//!   pluggable [`score::ScoreStore`] substrate — dense table or pruned
//!   hash table), [`priors`], and the candidate-parent restriction
//!   subsystem [`restrict`] (pairwise G² screening plus an optional
//!   MMPC-style conditional pass into per-node native-ragged
//!   [`combinatorics::RestrictedLayout`] pools — `--restrict
//!   mi:<k>[+mmpc]`, the 60+/128+-node scaling route)
//! * the learner: [`mcmc`] (Metropolis–Hastings over orders) driving a
//!   pluggable [`scorer`] engine — serial ("GPP"), baselines, or the
//!   AOT-compiled XLA executable loaded by [`runtime`] (behind the
//!   `xla` cargo feature)
//! * posterior inference: [`posterior`] (exact per-order edge marginals,
//!   PSRF/ESS convergence diagnostics, consensus graphs, checkpointed
//!   multi-chain sampling) — `--posterior` runs
//! * evaluation: [`eval`] (ROC / SHD), experiment drivers in `examples/`
//!   and `benches/`, orchestrated through [`coordinator`] — whose
//!   [`coordinator::registry`] is the single place engines and stores
//!   are paired (`--engine … --store dense|hash`)
//! * the service layer: [`service`] (the `serve` subcommand's daemon —
//!   JSON-lines TCP protocol, async job queue, shared score-store
//!   cache, streaming progress, cooperative cancellation, and the
//!   `--http-addr` observability endpoint serving `GET /metrics`)
//! * observability: [`telemetry`] (process-wide metrics registry,
//!   per-layer metric handles, `crate::span!` RAII trace timers) —
//!   written to by every layer above, rendered by the service layer's
//!   HTTP endpoint and the CLI's `--metrics-out`; strictly passive
//!   (never read back by the algorithms it observes).

// Carried codebase idioms clippy dislikes but that read better here
// (index-parallel loops over node/subset grids, paper-shaped argument
// lists, worker-bucket scaffolding types).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::manual_memcpy
)]

pub mod bn;
pub mod combinatorics;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exec;
pub mod mcmc;
pub mod networks;
pub mod posterior;
pub mod priors;
pub mod restrict;
pub mod runtime;
pub mod score;
pub mod scorer;
pub mod service;
pub mod telemetry;
pub mod util;
